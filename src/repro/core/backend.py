"""Pluggable inference backends for the Flexi-NeurA simulator.

The simulator exposes one seam -- :class:`InferenceBackend` -- through which
every consumer (training eval, the Flex-plorer DSE, serving, benchmarks)
runs a network.  Three backends ship here:

``reference``
    The paper-faithful step-major simulation: one ``jax.lax.scan`` over time
    steps, each step walking every core via ``int_layer_step`` /
    ``float_layer_step``.  This is the numerics contract.

``fused``
    Layer-major traversal that wires the Pallas kernels into the simulator:
    each eligible core's whole window runs as an exact int spike-weight
    matmul (``repro.kernels.quant_matmul.spike_matmul``) feeding the fused
    membrane scan (``repro.kernels.lif_scan``).  Bit-identical to
    ``reference`` by construction (both reduce to ``int_layer_step``'s
    arithmetic); the parity suite in ``tests/test_backend_parity.py`` holds
    it to that.

``event``
    Layer-major *event-driven* traversal: per layer, only the active
    pre-synaptic rows are gathered and summed (a masked-gather / segment-sum
    over a static event budget sized from the measured spike raster), so
    integration work scales with spike counts, not dense layer size --
    the execution model that underpins the paper's latency/energy story.
    Bit-exact to ``reference`` on every config (int32 accumulation is
    order-independent and the step dynamics are shared); transparently falls
    back to the dense window when a layer's traffic is too dense for the
    sparse path to win.  Three strategies: ``"csr"`` (host scipy, the eager
    CPU champion), ``"gather"`` (jnp masked gather), and ``"pallas"`` (the
    jit-compatible fixed-capacity event path through
    ``repro.kernels.sparse_accum`` -- the one that composes with
    ``shard_map`` and the serving engine's jitted lane tick).

Fused-path coverage matrix (per layer; ineligible layers transparently run
the reference step scan inside the fused traversal, so mixed networks work):

    neuron     topology   reset              fused kernel path?
    ---------  ---------  -----------------  ----------------------------
    IF / LIF   FF         zero / subtract    yes (matmul + lif_scan)
    IF / LIF   ATA_F/T    any                no  (recurrence couples steps)
    SYNAPTIC   any        any                no  (second state register)

The event path instead covers *every* row of that matrix sparsely: the
sparse gather computes only the feed-forward accumulation, and the shared
step scan (``int_layer_window_from_currents``) layers recurrent integration
and phase B on top, so recurrent and Synaptic cores stay on the sparse path.

Layer-major traversal is legal because inter-core traffic is strictly
feed-forward and step-aligned (a spike emitted at step t is consumed by the
next core at its step t); only *intra*-layer recurrence couples consecutive
steps, and those layers stay on the step scan.

Adding a backend: subclass :class:`InferenceBackend`, implement ``run_int``
(and optionally ``run_float``), then ``register_backend("name", Factory)``.
Everything above ``network.run_int`` selects backends by name, so new
execution strategies (multi-core mapping, event-driven, remote) plug in
without touching callers.  A backend that sizes buffers from concrete data
(like ``event``'s csr/gather strategies) sets ``jit_compatible = False``;
callers that would wrap ``run_int`` in their own ``jax.jit`` (e.g.
``eval_int``) then let the backend manage compilation itself, and sharding
callers may ask for a jit-compatible stand-in via ``jit_surrogate`` before
abandoning a mesh.

This module also hosts the population-batched integer simulation used by
the Flex-plorer's population DSE mode: a whole batch of precision
candidates -- same static network structure, different quantized weights,
thresholds and CG decay registers -- runs through one jitted, vmapped
program (``run_int_population``), eliminating the per-candidate
recompile-and-run that dominates serial DSE wall-clock.

The same one-compiled-program-many-lanes idea, batched over *samples*
instead of candidates, is exposed as the serving seam: ``batched_lane_init``
/ ``batched_lane_window`` advance a fixed pool of independent sample lanes
by a chunk of time steps per jitted call (what ``repro.serve.snn_engine``
drives for continuous batching), and ``run_int_batched`` runs a whole
ragged batch of variable-length samples through one jitted scan.  Each lane's
trajectory is bit-exact with a serial single-sample ``run_int``: the step
dynamics are elementwise/matmul over the batch axis, so batching lanes is
semantically a ``jax.vmap`` of the single-sample step.

Both batching axes (samples here, candidates in the population sweep) are
*independent* work, which is what lets ``repro.core.shard`` spread them
across devices bit-exactly -- see that module for the multi-device
execution layer built on these entry points.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixed_point import int_max
from repro.core.snn_layer import (
    IntLayerParams,
    ResetMode,
    fused_eligible,
    float_layer_init,
    float_layer_step,
    int_layer_init,
    int_layer_step,
    int_layer_step_dynamic,
    int_layer_window,
    int_layer_window_carry,
    int_layer_window_from_currents,
)
from repro.kernels.lif_scan.lif_scan import lif_scan
from repro.kernels.lif_scan.ref import lif_scan_ref
from repro.kernels.quant_matmul.spike_matmul import spike_integrate
from repro.kernels.sparse_accum.ops import sparse_accum_currents

__all__ = [
    "SimRecord",
    "InferenceBackend",
    "ReferenceBackend",
    "FusedBackend",
    "EventBackend",
    "register_backend",
    "get_backend",
    "available_backends",
    "check_population_structure",
    "stack_population",
    "run_int_population",
    "batched_lane_init",
    "batched_lane_window",
    "batched_lane_tick",
    "run_int_batched",
]


@dataclasses.dataclass
class SimRecord:
    """Outputs of a full-window simulation.

    spike_counts -- [batch, n_classes] output-layer spike totals (rate code)
    layer_spikes -- list over layers of [T, batch] per-step spike totals
                    (ASPL events *emitted* by that layer; layer l's entry is
                    what layer l+1 integrates at its step t)
    input_events -- [T, batch] per-step ASPL counts into layer 0 (the input
                    raster's active channels; what core 0 integrates)

    Every backend populates all three fields, so any record can drive the
    event-count-calibrated latency/energy model in ``repro.core.hw_model``
    (see ``EventTraffic.from_record``).
    """

    spike_counts: jax.Array
    layer_spikes: list[jax.Array]
    input_events: jax.Array | None = None

    def predictions(self):
        return jnp.argmax(self.spike_counts, axis=-1)

    def event_stats(self) -> dict:
        """Batch-mean event traffic: the latency/energy model's inputs.

        Returns ``{"input_events_per_step": [T], "layer_events_per_step":
        list over layers of [T]}`` as numpy arrays (mean over the batch) --
        the same shape ``eval_int(..., return_stats=True)`` aggregates over
        a whole dataset.
        """
        if self.input_events is None:
            raise ValueError("record carries no input_events (legacy record?)")
        return {
            "input_events_per_step": np.asarray(jnp.mean(self.input_events, axis=1)),
            "layer_events_per_step": [
                np.asarray(jnp.mean(s, axis=1)) for s in self.layer_spikes
            ],
        }

    def total_events_per_image(self) -> float:
        """Mean events per sample over the whole window (input + emitted)."""
        if self.input_events is None:
            raise ValueError("record carries no input_events (legacy record?)")
        total = jnp.sum(jnp.mean(self.input_events, axis=1))
        for s in self.layer_spikes:
            total = total + jnp.sum(jnp.mean(s, axis=1))
        return float(total)


def _run_step_major(net, params, spikes_in, init_fn, step_fn) -> SimRecord:
    """Step-major simulation: scan over time, walk the cores inside."""
    batch = spikes_in.shape[1]
    states = [init_fn(cfg, batch) for cfg in net.layers]

    def one_step(states, s_t):
        new_states = []
        x = s_t
        emitted = []
        for cfg, p, st in zip(net.layers, params, states):
            st, x = step_fn(cfg, p, st, x)
            new_states.append(st)
            emitted.append(jnp.sum(x, axis=-1))  # events per sample this step
        return new_states, (x, jnp.stack(emitted, axis=0))

    states, (out_spikes, emitted) = jax.lax.scan(one_step, states, spikes_in)
    counts = jnp.sum(out_spikes, axis=0)
    layer_spikes = [emitted[:, i, :] for i in range(len(net.layers))]
    input_events = jnp.sum(spikes_in != 0, axis=-1)
    return SimRecord(
        spike_counts=counts, layer_spikes=layer_spikes, input_events=input_events
    )


class InferenceBackend:
    """One execution strategy for a full-window network simulation."""

    name = "base"
    #: True when ``run_int`` may be traced under a caller's ``jax.jit``.
    #: Backends that size buffers from concrete data (event-driven) set this
    #: False and manage jit compilation internally; callers like ``eval_int``
    #: check it before wrapping.
    jit_compatible = True

    def run_int(self, net, qparams: Sequence[IntLayerParams], spikes_in) -> SimRecord:
        raise NotImplementedError

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        raise NotImplementedError

    def jit_surrogate(self, net, spikes_in) -> "InferenceBackend | None":
        """A jit-compatible stand-in carrying this backend's numerics, or None.

        Sharding callers (``run_int_sharded``) ask for one before abandoning
        a multi-device mesh on a ``jit_compatible = False`` backend; returning
        ``None`` means the backend is irreplaceably host-side and the caller
        should fall back to the serial path.
        """
        return None


class ReferenceBackend(InferenceBackend):
    """Step-major jnp semantics -- the numerics contract for every backend."""

    name = "reference"

    # The reference backend has no configuration knobs, so any two
    # instances are interchangeable: compare (and hash) by value so callers
    # passing an explicit ReferenceBackend() are recognised as the default
    # (the population-mode warning in explore_snn keys off this).
    def __eq__(self, other) -> bool:
        return type(other) is type(self)

    def __hash__(self) -> int:
        return hash((type(self).__module__, type(self).__qualname__))

    def run_int(self, net, qparams, spikes_in) -> SimRecord:
        return _run_step_major(
            net, list(qparams), spikes_in.astype(jnp.int32), int_layer_init, int_layer_step
        )

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        def step(cfg, p, st, x):
            return float_layer_step(cfg, p, st, x, spike_fn)

        return _run_step_major(
            net, list(params), spikes_in.astype(jnp.float32), float_layer_init, step
        )


class FusedBackend(InferenceBackend):
    """Layer-major traversal through the fused integration + membrane kernels.

    ``use_pallas`` selects the Pallas kernels (default: only on TPU; the
    pure-jnp window oracle carries the identical numerics elsewhere, which
    keeps CPU/GPU runs fast -- interpret-mode Pallas is a debugging tool,
    not a fast path).  ``interpret`` forces interpreter execution of the
    kernels off-TPU; the parity suite uses ``use_pallas=True,
    interpret=True`` to hold the *actual kernels* to the bit-exact contract
    on CPU.
    """

    name = "fused"

    def __init__(
        self,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        block_b: int = 8,
        block_n: int = 128,
    ):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block_b = block_b
        self.block_n = block_n

    def _pallas_enabled(self) -> bool:
        if self.use_pallas is None:
            return jax.default_backend() == "tpu"
        return self.use_pallas

    def _interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def _fused_layer_window(self, cfg, p: IntLayerParams, raster):
        """Whole-window spikes for one FF IF/LIF core via the kernel pair."""
        use_pallas = self._pallas_enabled()
        currents = spike_integrate(
            raster, p.w_ff, use_pallas=use_pallas, interpret=self._interpret()
        )
        code = cfg.beta_code()
        decay_k = 256 if code.bypass else code.k
        reset_to_zero = cfg.reset == ResetMode.ZERO
        try:
            theta_q = int(p.theta_q)  # static for the Pallas kernel
        except (
            jax.errors.TracerIntegerConversionError,
            jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError,
        ):
            theta_q = None  # traced weights (e.g. under vmap): oracle only
        T, B, N = currents.shape
        bb, bn = min(self.block_b, B), min(self.block_n, N)
        if theta_q is None or not use_pallas or B % bb or N % bn:
            theta = p.theta_q if theta_q is None else theta_q
            spikes, _ = lif_scan_ref(currents, theta, decay_k, cfg.u_bits, reset_to_zero)
            return spikes
        spikes, _ = lif_scan(
            currents,
            theta_q=theta_q,
            decay_k=decay_k,
            u_bits=cfg.u_bits,
            reset_to_zero=reset_to_zero,
            block_b=bb,
            block_n=bn,
            interpret=self._interpret(),
        )
        return spikes

    def run_int(self, net, qparams, spikes_in) -> SimRecord:
        x = spikes_in.astype(jnp.int32)
        input_events = jnp.sum(x != 0, axis=-1)
        emitted = []
        for cfg, p in zip(net.layers, qparams):
            if fused_eligible(cfg):
                x = self._fused_layer_window(cfg, p, x)
            else:
                x = int_layer_window(cfg, p, x)
            emitted.append(jnp.sum(x, axis=-1))  # [T, batch]
        counts = jnp.sum(x, axis=0)
        return SimRecord(
            spike_counts=counts, layer_spikes=emitted, input_events=input_events
        )

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        # The fused kernels are integer-only; float (training) simulation
        # keeps the differentiable reference semantics.
        return ReferenceBackend().run_float(net, params, spikes_in, spike_fn)


# ---------------------------------------------------------------------------
# Event-driven backend: work scales with spike counts, not dense layer size
# ---------------------------------------------------------------------------

try:  # the host CSR strategy wants scipy's C sparse kernels; optional
    import scipy.sparse as _scipy_sparse
except ImportError:  # pragma: no cover - scipy ships with jax, but stay safe
    _scipy_sparse = None


def _round_capacity(k: int, multiple: int = 16) -> int:
    """Round an event budget up to a lane-aligned multiple (bounds the
    number of distinct compiled programs and keeps the gather shapes
    vector-unit/Pallas friendly)."""
    return max(multiple, ((k + multiple - 1) // multiple) * multiple)


def _gather_currents(raster, w_ff, k_active: int):
    """Sparse FF integration: sum only the active pre-synaptic weight rows.

    ``raster`` int32 [T, B, n_in]; ``k_active`` is a static per-window event
    budget >= the max active-channel count of any (t, b).  ``top_k`` on the
    spike vector compacts the active source addresses to the front (the
    returned values double as the per-lane spike values, so over-budget
    lanes contribute exact zeros), then the masked gather-and-sum computes
    ``s_t @ w_ff`` touching k_active rows instead of n_in.  int32 addition
    is order-independent, so the result is bit-identical to the dense
    einsum for any sufficient budget.
    """
    T, B, n_in = raster.shape
    flat = raster.reshape(T * B, n_in).astype(jnp.int32)
    vals, idx = jax.lax.top_k(flat, k_active)  # per-lane values: 0 = padding
    rows = w_ff[idx]  # [T*B, k_active, n_out] gather of active rows
    currents = jnp.einsum("ek,eko->eo", vals, rows.astype(jnp.int32))
    return currents.reshape(T, B, -1)


def _csr_currents(
    raster: np.ndarray,
    w_ff: np.ndarray,
    active: np.ndarray,
    row_counts: np.ndarray,
) -> np.ndarray:
    """Host-side sparse FF integration through scipy's C CSR kernel.

    ``np.flatnonzero`` on the (caller-precomputed) activity mask *is* the
    CSR column structure (row-major order) and the per-row event counts
    *are* the indptr, so assembly is one C pass plus O(nnz) address
    arithmetic; the CSR x dense product then costs O(nnz * n_out) -- true
    event-count-proportional work.  Exact int32, same wraparound semantics
    as the dense einsum.
    """
    T, B, n_in = raster.shape
    rows = T * B
    nz = np.flatnonzero(active)
    c = (nz % n_in).astype(np.int32)
    data = np.ascontiguousarray(raster).reshape(-1)[nz].astype(np.int32, copy=False)
    indptr = np.zeros(rows + 1, np.int64)
    np.cumsum(row_counts.reshape(-1), out=indptr[1:])
    mat = _scipy_sparse.csr_matrix((data, c, indptr), shape=(rows, n_in))
    currents = np.asarray(mat @ w_ff.astype(np.int32, copy=False), np.int32)
    return currents.reshape(T, B, -1)


@functools.partial(jax.jit, static_argnames=("cfg", "k_active"))
def _event_layer_window(cfg, params: IntLayerParams, raster, k_active: int):
    currents = _gather_currents(raster, params.w_ff, k_active)
    return int_layer_window_from_currents(cfg, params, currents)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase_b_window(cfg, params: IntLayerParams, currents):
    return int_layer_window_from_currents(cfg, params, currents)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _dense_layer_window(cfg, params: IntLayerParams, raster):
    """Density fallback: whole-window flat dense integration (one einsum
    over [T*B, n_in], the fused backend's shape) feeding the same step scan
    -- so even the fallback beats the step-major reference on wall-clock."""
    currents = spike_integrate(raster, params.w_ff, use_pallas=False)
    return int_layer_window_from_currents(cfg, params, currents)


@functools.partial(
    jax.jit, static_argnames=("cfg", "budget", "f32_exact", "use_pallas", "interpret")
)
def _fixed_layer_window(
    cfg, params: IntLayerParams, raster, budget, f32_exact, use_pallas, interpret
):
    """One layer's window through the fixed-capacity sparse accumulate.

    ``budget`` is the static event budget (``None`` = the density fallback:
    dense integration at the same lowering choices); ``f32_exact`` certifies
    the f32 BLAS exactness bound for the off-TPU lowering (see
    ``repro.kernels.sparse_accum.ops``).  Traceable end to end -- this is
    the layer window the pallas strategy runs under an outer ``jax.jit`` /
    ``shard_map``.
    """
    if budget is None:
        if f32_exact:
            currents = _ff_currents_f32_exact(raster, params.w_ff)
        else:
            currents = spike_integrate(raster, params.w_ff, use_pallas=False)
    else:
        currents = sparse_accum_currents(
            raster,
            params.w_ff,
            budget,
            f32_exact=f32_exact,
            use_pallas=use_pallas,
            interpret=interpret,
        )
    return int_layer_window_from_currents(cfg, params, currents)


class EventBackend(InferenceBackend):
    """Event-driven layer-major traversal: integrate active rows, skip silence.

    Per layer, only the active pre-synaptic rows contribute to the window's
    feed-forward integration; the shared step scan
    (``int_layer_window_from_currents``) then applies recurrent integration
    and phase B.  Work and memory traffic scale with spike counts -- the
    same contract the hardware's AER pipeline (and the latency model in
    ``hw_model``) obeys.  Two sparse strategies carry identical numerics:

    ``"gather"``
        The jnp masked-gather formulation: ``top_k`` compacts active source
        addresses into a static event budget sized from the *measured* max
        per-step event count (lane-rounded, see ``_round_capacity``), then a
        masked gather-and-sum touches budget rows instead of n_in.  Fully
        jit-compiled; the shape XLA:TPU / a Pallas kernel wants.

    ``"csr"``
        Host-side CSR x dense product through scipy's C kernel: O(nnz *
        n_out) work.  On CPU, XLA's gather/scatter lower to code that loses
        to its own dense matmul even at 5% density, so this is the strategy
        that actually realises the event-driven win there (the benchmark in
        ``benchmarks/event_bench.py`` holds it to that).  Host-side by
        construction: ``jit_compatible = False``, raises under tracing.

    ``"pallas"``
        The jit-compatible fixed-capacity event path
        (``repro.kernels.sparse_accum``): the raster is AER-encoded into a
        static, lane-rounded event budget and scattered through the Pallas
        kernel on TPU; off-TPU the identical int32 numerics run through the
        budget-certified exact-f32 BLAS lowering (or the int einsum when
        the certificate fails), so the strategy stays *faster than the
        dense int path* while remaining a single traceable program.  This
        is the strategy that survives ``jax.jit`` / ``shard_map`` / the
        serving engine's jitted lane tick: ``jit_compatible = True``.

    ``"auto"`` (default) picks ``gather`` on TPU and ``csr`` elsewhere when
    scipy is available -- the eager champions -- and promotes to
    ``"pallas"`` whenever ``run_int`` is invoked under tracing, so
    ``backend="event"`` composes with outer ``jax.jit`` / ``vmap`` without
    losing sparsity.

    ``event_budget`` (optional static int) pins the layer-0 event budget for
    the traced pallas path, where there are no concrete spike counts to
    measure; unset, tracing uses full capacity for safety and eager runs
    measure per layer.  It is a *capacity contract*: callers guarantee no
    (step, sample) row carries more active channels than the budget (the
    serving engine enforces this at admission; ``jit_surrogate`` measures it
    from the concrete rasters).  ``input_max_val`` (static int, default 1 =
    binary spike rasters, the repo-wide raster contract) bounds input values
    for the same traced path: together with the budget it certifies the
    exact-f32 lowering (``input_max_val * budget * int_max(w_bits) <
    2**24``); graded rasters above the declared bound fall back to the
    exact int einsum.  Deeper layers need no declaration -- phase-B spikes
    are {0,1}, which certifies every supported core size.

    Bit-exact to ``reference`` on every neuron model x topology x reset mode
    (asserted by the parity suite): both strategies compute the identical
    int32 feed-forward sum -- int32 addition is order-independent, and
    saturation only applies after the full step's accumulation -- and the
    dynamics reuse the reference step numerics.  Two transparent fallbacks
    keep the contract without a perf cliff:

    * density: a layer whose event budget exceeds ``dense_threshold * n_in``
      runs the dense window instead (sparse indirection loses to the dense
      matmul well below 100% density);
    * tracing: under an outer ``jax.jit`` / ``vmap`` the csr and gather
      strategies have no concrete spike counts to size buffers from, so
      ``auto`` (and ``gather``) promote to the fixed-capacity pallas path
      -- still bit-exact, still one compiled program.  An *explicitly*
      selected ``csr`` raises instead: host-side scipy cannot trace.
    """

    name = "event"
    jit_compatible = False  # class default; pallas instances override below

    def __init__(
        self,
        strategy: str = "auto",
        dense_threshold: float = 0.34,
        capacity_multiple: int = 16,
        event_budget: int | None = None,
        input_max_val: int = 1,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
    ):
        if strategy not in ("auto", "gather", "csr", "pallas"):
            raise ValueError(f"unknown event strategy {strategy!r}")
        if strategy == "csr" and _scipy_sparse is None:
            raise ValueError("event strategy 'csr' needs scipy installed")
        if not 0.0 < dense_threshold <= 1.0:
            raise ValueError(f"dense_threshold must be in (0, 1], got {dense_threshold}")
        if not isinstance(capacity_multiple, int) or capacity_multiple < 1:
            raise ValueError(f"capacity_multiple must be a positive int, got {capacity_multiple}")
        if event_budget is not None and (not isinstance(event_budget, int) or event_budget < 1):
            raise ValueError(f"event_budget must be a positive int or None, got {event_budget}")
        if not isinstance(input_max_val, int) or input_max_val < 1:
            raise ValueError(f"input_max_val must be a positive int, got {input_max_val}")
        self.strategy = strategy
        self.dense_threshold = dense_threshold
        self.capacity_multiple = capacity_multiple
        self.event_budget = event_budget
        self.input_max_val = input_max_val
        self.use_pallas = use_pallas
        self.interpret = interpret
        # The fixed-capacity path is one traceable program; the measured
        # eager strategies are not.
        self.jit_compatible = strategy == "pallas"

    # Value identity: backend instances ride through ``jax.jit`` static
    # arguments (shard_map, the sharded eval path), so equal configurations
    # must hash equal or every fresh instance would recompile the world.
    def _static_key(self):
        return (
            self.strategy,
            self.dense_threshold,
            self.capacity_multiple,
            self.event_budget,
            self.input_max_val,
            self.use_pallas,
            self.interpret,
        )

    def __eq__(self, other):
        return isinstance(other, EventBackend) and self._static_key() == other._static_key()

    def __hash__(self):
        return hash(self._static_key())

    def resolved_strategy(self, traced: bool = False) -> str:
        if self.strategy != "auto":
            return self.strategy
        if traced:
            return "pallas"
        if jax.default_backend() == "tpu" or _scipy_sparse is None:
            return "gather"
        return "csr"

    def _budget(self, x_counts_max: int, cfg) -> int:
        return min(cfg.n_in, _round_capacity(x_counts_max, self.capacity_multiple))

    def static_budget(self, n_in: int, k_max: int | None = None) -> int:
        """The static lane-rounded event budget for a layer of width ``n_in``.

        Priority: the configured ``event_budget`` (lane-rounded, capped at
        ``n_in``), else the measured ``k_max``, else full capacity (the safe
        traced default: every lowering stays exact, sparsity is just not
        exploited until a budget is declared or measured).
        """
        if self.event_budget is not None:
            k = self.event_budget
        elif k_max is not None:
            k = k_max
        else:
            return n_in
        return min(n_in, _round_capacity(k, self.capacity_multiple))

    def serve_budget(self, n_in: int, admission_threshold: float) -> int:
        """The event budget a serving engine compiles its sparse lane program at.

        The configured ``event_budget`` wins; otherwise 2x the admission
        density (lane-rounded) -- room for a request's max *step* to run
        twice as hot as its admission-checked *mean* without re-routing.
        """
        if self.event_budget is not None:
            return self.static_budget(n_in)
        k = max(1, int(2 * admission_threshold * n_in))
        return min(n_in, _round_capacity(k, self.capacity_multiple))

    def _f32_certified(self, cfg, budget: int | None, max_val: int) -> bool:
        """True when the budget bound certifies the exact-f32 lowering."""
        rows = cfg.n_in if budget is None else min(budget, cfg.n_in)
        return int_max(cfg.w_bits) * rows * max_val < 2**24

    def run_int(self, net, qparams, spikes_in) -> SimRecord:
        x = jnp.asarray(spikes_in)
        traced = isinstance(x, jax.core.Tracer)
        strategy = self.resolved_strategy(traced=traced)
        if traced and strategy == "csr":
            raise ValueError(
                "event strategy 'csr' is host-side (scipy) and cannot run under "
                "jit/vmap tracing; use strategy='pallas' (the jit-compatible "
                "fixed-capacity path) or call it eagerly"
            )
        x = x.astype(jnp.int32)
        if strategy == "csr":
            return self._run_int_csr(net, qparams, np.asarray(x))
        if strategy == "pallas" or traced:
            return self._run_int_fixed(net, qparams, x, traced)
        input_events = jnp.sum(x != 0, axis=-1)
        emitted = []
        for cfg, p in zip(net.layers, qparams):
            k_max = int(jnp.max(jnp.sum(x != 0, axis=-1)))  # concrete: host value
            k = self._budget(k_max, cfg)
            if k > self.dense_threshold * cfg.n_in:
                x = _dense_layer_window(cfg, p, x)
            else:
                x = _event_layer_window(cfg, p, x, k)
            emitted.append(jnp.sum(x, axis=-1))  # [T, batch]
        counts = jnp.sum(x, axis=0)
        return SimRecord(
            spike_counts=counts, layer_spikes=emitted, input_events=input_events
        )

    def _run_int_fixed(self, net, qparams, x, traced: bool) -> SimRecord:
        """The fixed-capacity (pallas-strategy) traversal.

        Eager runs measure per-layer budgets and input magnitude exactly as
        the gather strategy does; traced runs take the static budget
        (``static_budget``) and the declared ``input_max_val`` for layer 0,
        full capacity for deeper layers (phase-B spikes are {0,1}, so the
        f32 certificate holds at any supported size).  Either way every
        layer is one traceable ``_fixed_layer_window`` call -- the whole run
        composes with an outer ``jax.jit`` / ``shard_map``.
        """
        input_events = jnp.sum(x != 0, axis=-1)
        emitted = []
        max_val = self.input_max_val if traced else max(1, int(jnp.max(x)))
        for i, (cfg, p) in enumerate(zip(net.layers, qparams)):
            if traced:
                budget = self.static_budget(cfg.n_in) if i == 0 else cfg.n_in
            else:
                k_max = int(jnp.max(jnp.sum(x != 0, axis=-1)))
                budget = self.static_budget(cfg.n_in, k_max=k_max)
            if budget > self.dense_threshold * cfg.n_in:
                budget = None  # density fallback: dense lowering, same numerics
            f32_ok = self._f32_certified(cfg, budget, max_val)
            x = _fixed_layer_window(
                cfg, p, x, budget, f32_ok, self.use_pallas, self.interpret
            )
            emitted.append(jnp.sum(x, axis=-1))  # [T, batch]
            max_val = 1  # phase B emits {0,1}
        counts = jnp.sum(x, axis=0)
        return SimRecord(
            spike_counts=counts, layer_spikes=emitted, input_events=input_events
        )

    def jit_surrogate(self, net, spikes_in) -> "EventBackend | None":
        """A pallas-strategy twin for sharding callers, or None for csr.

        ``auto``/``gather``/``pallas`` all carry identical numerics through
        the fixed-capacity path, so a mesh partition need not be abandoned:
        the surrogate pins the layer-0 budget (configured, else measured
        from the concrete rasters -- lane-rounding bounds the number of
        distinct compiled programs) and the measured input magnitude.  An
        *explicit* ``csr`` selection is an opt-in to the host-side path and
        returns None: the caller warns and runs serially.
        """
        if self.strategy == "csr":
            return None
        budget = self.event_budget
        input_max_val = self.input_max_val
        x = jnp.asarray(spikes_in)
        if not isinstance(x, jax.core.Tracer):
            if budget is None:
                budget = max(1, int(jnp.max(jnp.sum(x != 0, axis=-1))))
            input_max_val = max(input_max_val, int(jnp.max(x)))
        return EventBackend(
            strategy="pallas",
            dense_threshold=self.dense_threshold,
            capacity_multiple=self.capacity_multiple,
            event_budget=budget,
            input_max_val=input_max_val,
            use_pallas=self.use_pallas,
            interpret=self.interpret,
        )

    def _run_int_csr(self, net, qparams, x: np.ndarray) -> SimRecord:
        """Host-driven traversal: numpy event bookkeeping, scipy CSR
        integration, jitted phase-B scans.  On the CPU jax backend the
        host/device handoffs are zero-copy, so the only real work is the
        activity pass (the AER encoder's job), the O(nnz * n_out) sparse
        product, and the phase-B scan."""
        active = x != 0  # [T, batch, n_in] byte mask, reused by the CSR build
        counts = active.sum(axis=-1)  # [T, batch]
        input_events = counts
        emitted = []
        for cfg, p in zip(net.layers, qparams):
            k = self._budget(int(counts.max(initial=0)), cfg)
            if k > self.dense_threshold * cfg.n_in:
                x = np.asarray(_dense_layer_window(cfg, p, jnp.asarray(x)))
                active = x != 0
                counts = active.sum(axis=-1)
            else:
                currents = _csr_currents(x, np.asarray(p.w_ff), active, counts)
                x = np.asarray(_phase_b_window(cfg, p, jnp.asarray(currents)))
                # phase B emits {0,1}: the spike raster is its own mask and
                # its sum doubles as the next layer's event count
                active = x
                counts = x.sum(axis=-1)
            emitted.append(counts)
        return SimRecord(
            spike_counts=jnp.asarray(x.sum(axis=0)),
            layer_spikes=[jnp.asarray(e) for e in emitted],
            input_events=jnp.asarray(input_events),
        )

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        # Float (training) simulation keeps the differentiable reference
        # semantics; sparsity games don't pay off under surrogate gradients.
        return ReferenceBackend().run_float(net, params, spikes_in, spike_fn)


_REGISTRY: dict[str, Callable[[], InferenceBackend]] = {}


def register_backend(name: str, factory: Callable[[], InferenceBackend]) -> None:
    """Register a backend factory under ``name`` (later wins, like a config)."""
    _REGISTRY[name] = factory


def get_backend(backend: str | InferenceBackend) -> InferenceBackend:
    """Resolve a backend selector: a registered name or an instance."""
    if isinstance(backend, InferenceBackend):
        return backend
    try:
        return _REGISTRY[backend]()
    except KeyError:
        raise ValueError(
            f"unknown inference backend {backend!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


register_backend("reference", ReferenceBackend)
register_backend("fused", FusedBackend)
register_backend("event", EventBackend)


# ---------------------------------------------------------------------------
# Population-batched integer simulation (the Flex-plorer DSE hot path)
# ---------------------------------------------------------------------------


# Layer fields a population sweep may vary per candidate: they only reach the
# traced program through quantized values / decay registers.  Everything else
# is static (baked into the one compiled program) and must match the base net.
_POPULATION_KNOBS = ("w_bits", "w_rec_bits", "leak_bits", "beta", "alpha")


def check_population_structure(base, nets) -> None:
    """Raise unless every candidate shares ``base``'s static structure."""
    base_sig = [
        {f.name: getattr(lc, f.name) for f in dataclasses.fields(lc) if f.name not in _POPULATION_KNOBS}
        for lc in base.layers
    ]
    for net in nets:
        if len(net.layers) != len(base.layers):
            raise ValueError(
                f"population candidate {net.name!r} has {len(net.layers)} layers, base has {len(base.layers)}"
            )
        for i, lc in enumerate(net.layers):
            for name, want in base_sig[i].items():
                got = getattr(lc, name)
                if got != want:
                    raise ValueError(
                        f"population candidate {net.name!r} layer {i} differs from the "
                        f"base net in static field {name!r} ({got!r} != {want!r}); only "
                        f"{_POPULATION_KNOBS} may vary across a population sweep"
                    )


def stack_population(nets, qparams_list):
    """Stack per-candidate quantized parameters for a vmapped evaluation.

    ``nets`` are per-candidate :class:`NetworkConfig`s sharing one static
    structure (layer count/shapes/neuron/topology/reset/register widths --
    exactly what the DSE holds fixed while varying ``w_bits`` /
    ``w_rec_bits`` / ``leak_bits``); ``qparams_list`` the matching
    ``quantize_params`` outputs.  Returns ``(stacked_qparams, beta_regs,
    alpha_regs)`` where each stacked leaf gains a leading candidate axis and
    the decay registers are int32 ``[P, n_layers]`` packed DecayRate values.
    """
    n_layers = len(nets[0].layers)
    stacked = [
        IntLayerParams(
            w_ff=jnp.stack([qp[l].w_ff for qp in qparams_list]),
            w_rec=jnp.stack([qp[l].w_rec for qp in qparams_list]),
            theta_q=jnp.stack([qp[l].theta_q for qp in qparams_list]),
        )
        for l in range(n_layers)
    ]
    beta_regs = jnp.asarray(
        [[cfg.beta_code().decay_rate_register for cfg in net.layers] for net in nets],
        jnp.int32,
    )
    alpha_regs = jnp.asarray(
        [[cfg.alpha_code().decay_rate_register for cfg in net.layers] for net in nets],
        jnp.int32,
    )
    return stacked, beta_regs, alpha_regs


def _run_int_dynamic(net, qparams, beta_regs, alpha_regs, spikes_in):
    """One candidate's bit-exact run with traced decay registers.

    Numerically identical to ``ReferenceBackend.run_int`` (the dynamic step
    gates the same shift taps arithmetically); exists so the decay registers
    can differ across vmapped candidates.  Returns ``(spike_counts [batch,
    n_classes], emitted [T, n_layers, batch])`` -- the emitted per-step event
    totals feed the event-aware DSE cost model.
    """
    batch = spikes_in.shape[1]
    states = [int_layer_init(cfg, batch) for cfg in net.layers]

    def one_step(states, s_t):
        new_states = []
        x = s_t
        emitted = []
        for i, (cfg, p, st) in enumerate(zip(net.layers, qparams, states)):
            st, x = int_layer_step_dynamic(cfg, p, st, x, beta_regs[i], alpha_regs[i])
            new_states.append(st)
            emitted.append(jnp.sum(x, axis=-1))
        return new_states, (x, jnp.stack(emitted, axis=0))

    _, (out_spikes, emitted) = jax.lax.scan(one_step, states, spikes_in)
    return jnp.sum(out_spikes, axis=0), emitted  # [batch, n_classes], [T, L, batch]


def run_int_population(
    net, stacked_qparams, beta_regs, alpha_regs, spikes_in, return_events: bool = False
):
    """Score P precision candidates in one vmapped sweep.

    ``spikes_in`` int [T, batch, n_in] is shared by all candidates (the DSE
    evaluates every candidate on the same held-out batch).  Returns int32
    spike counts [P, batch, n_classes]; with ``return_events``, also the
    per-candidate emitted event totals [P, T, n_layers, batch] (each
    candidate quantizes differently, so its event traffic -- and therefore
    its modeled latency/energy -- differs too).
    """
    spikes_in = spikes_in.astype(jnp.int32)

    def one(qp, beta, alpha):
        return _run_int_dynamic(net, qp, beta, alpha, spikes_in)

    counts, emitted = jax.vmap(one, in_axes=(0, 0, 0))(
        stacked_qparams, beta_regs, alpha_regs
    )
    if return_events:
        return counts, emitted
    return counts


# ---------------------------------------------------------------------------
# Batched lane stepping (the SNN serving engine's hot path)
# ---------------------------------------------------------------------------


def batched_lane_init(net, n_lanes: int) -> list:
    """Fresh per-layer states for a pool of ``n_lanes`` independent lanes.

    A *lane* holds one in-flight sample; lanes never interact (every step
    operation is elementwise or a matmul over the batch axis), so a pool of
    lanes at different local time steps evolves each lane exactly as a
    serial single-sample run would.
    """
    return [int_layer_init(cfg, n_lanes) for cfg in net.layers]


def lane_state_take(states, lane: int) -> list:
    """Snapshot one lane's per-layer carry out of a pool (host copy).

    The preemption seam: ``states`` is the pool from
    :func:`batched_lane_init` / :func:`batched_lane_window`; the returned
    per-layer :class:`LayerState` slices (numpy, detached from the pool's
    donated buffers) hold everything lane ``lane``'s trajectory needs to
    resume later -- membrane, synaptic current, previous spikes.  Restoring
    them with :func:`lane_state_put` and continuing the window from the
    same local step is bit-exact with an uninterrupted run (lanes never
    interact, so a lane's carry *is* its full simulation state).
    """
    return jax.tree.map(lambda a: np.asarray(a[lane]), states)


def lane_state_put(states, lane: int, carry) -> list:
    """Write a :func:`lane_state_take` snapshot back into a pool at
    ``lane`` (any slot -- the carry is placement-independent).  Returns the
    new pool states; other lanes are untouched."""
    return jax.tree.map(
        lambda a, v: a.at[lane].set(jnp.asarray(v, a.dtype)), states, carry
    )


def _ff_currents_f32_exact(x, w_ff):
    """Feed-forward chunk integration through the f32 BLAS path, bit-exactly.

    Every partial sum is an integer with magnitude <= max_spike * n_in *
    int_max(w_bits); the *caller* guarantees that bound stays below 2**24
    (f32's exact-integer range), so products, partial sums in any
    association order, and the final cast back to int32 are all exact.
    On CPU this routes the hot matmul through BLAS instead of XLA's naive
    integer loops.
    """
    T, B, n_in = x.shape
    flat = x.reshape(T * B, n_in).astype(jnp.float32)
    cur = flat @ w_ff.astype(jnp.float32)
    return cur.astype(jnp.int32).reshape(T, B, -1)


@functools.partial(jax.jit, static_argnames=("net", "ff_mode", "event_budget"))
def batched_lane_window(
    net,
    qparams,
    states,
    x_chunk,
    reset_mask,
    valid_steps=None,
    ff_mode="int32",
    event_budget=None,
):
    """Advance every lane by ``k`` time steps through the whole core stack.

    ``states``   -- list over layers of per-lane :class:`LayerState` (from
                    :func:`batched_lane_init`);
    ``x_chunk``  -- int [k, n_lanes, n_in], each active lane's raster
                    slice starting at its *own* local step (inactive lanes
                    and steps past a lane's window: zeros);
    ``reset_mask`` -- bool [n_lanes], lanes newly admitted since the last
                    call; their state is zeroed (== ``int_layer_init``)
                    before stepping, so admission never perturbs a lane's
                    bit-exact trajectory and freed lanes can be reused
                    immediately (continuous batching);
    ``valid_steps`` -- optional int [n_lanes]: per lane, how many of the
                    chunk's steps fall inside its own window.  Recorded
                    outputs are masked past a lane's validity (residual
                    membrane charge could otherwise keep firing on
                    zero-input padding steps), and the lane's *carry* is
                    frozen at the validity boundary (padding steps would
                    otherwise decay the membrane and advance ``prev_spk``),
                    so a lane may *complete mid-chunk* bit-exactly and its
                    post-chunk state is exactly the state after its last
                    valid step -- the seam streaming sessions snapshot and
                    resume from.  ``None`` records every step.

    Returns ``(states, out_spikes [k, n_lanes, n_classes], emitted
    [k, n_layers, n_lanes])`` -- the final layer's per-step spikes plus
    every layer's per-step per-lane emitted-event count (what per-request
    ``event_stats`` accumulates from).

    One jitted call advances all lanes ``k`` steps: per-call dispatch
    overhead -- not the tiny per-step arithmetic -- dominates a CPU/edge
    serving loop, so the engine amortises it over a chunk.  The program
    specialises on ``k``; callers bound compilation count by quantising
    ``k`` (the serving engine uses powers of two, with ``valid_steps``
    absorbing the overshoot past the earliest lane completion).

    The traversal is layer-major *within* the chunk (legal for the same
    reason the fused/event backends are: inter-core traffic is strictly
    feed-forward and step-aligned): each layer integrates its whole chunk
    in one feed-forward matmul and carries its state through the shared
    step scan (``int_layer_window_carry``), which layers recurrence and
    phase B on top -- so every neuron model / topology / reset mode is
    covered bit-exactly while the hot matmul runs at [k * n_lanes, n_in]
    instead of k separate [n_lanes, n_in] slivers.

    ``ff_mode`` (static) selects how the feed-forward matmul is computed:
    ``"int32"`` (exact by construction) or ``"f32_exact"``, which routes it
    through the f32 BLAS path -- still bit-exact *provided the caller has
    checked* ``max_spike_value * n_in * int_max(w_bits) < 2**24`` for every
    layer (the serving engine checks this per network and per request;
    deeper layers always qualify because phase-B spikes are {0,1}).

    ``event_budget`` (static) routes *layer 0* through the fixed-capacity
    sparse event path (``repro.kernels.sparse_accum``) at that budget: the
    Pallas AER scatter on TPU, the budget-certified exact-f32 lowering
    elsewhere.  The caller guarantees the capacity contract -- every active
    lane's chunk rows carry at most ``event_budget`` active channels with
    ``max_spike_value * event_budget * int_max(l0.w_bits) < 2**24`` (the
    serving engine enforces both at admission, see the ``"event-pallas"``
    route).  Deeper layers follow ``ff_mode`` as usual.
    """
    states = jax.tree.map(
        lambda a: jnp.where(reset_mask[:, None], jnp.zeros_like(a), a), states
    )
    k = x_chunk.shape[0]
    x = x_chunk.astype(jnp.int32)
    live = None
    if valid_steps is not None:
        live = jnp.arange(k)[:, None] < valid_steps[None, :]  # [k, n_lanes]
    new_states, emitted = [], []
    for li, (cfg, p, st) in enumerate(zip(net.layers, qparams, states)):
        if li == 0 and event_budget is not None:
            currents = sparse_accum_currents(x, p.w_ff, min(event_budget, cfg.n_in))
        elif ff_mode == "f32_exact":
            currents = _ff_currents_f32_exact(x, p.w_ff)
        else:
            currents = spike_integrate(x, p.w_ff, use_pallas=False)
        st, x = int_layer_window_carry(cfg, p, st, currents, live=live)
        new_states.append(st)
        emitted.append(jnp.sum(x, axis=-1))  # [k, n_lanes]
    out_spikes = x
    emitted = jnp.stack(emitted, axis=1)  # [k, n_layers, n_lanes]
    if live is not None:
        live_i = live.astype(jnp.int32)
        out_spikes = out_spikes * live_i[:, :, None]
        emitted = emitted * live_i[:, None, :]
    return new_states, out_spikes, emitted


def batched_lane_tick(net, qparams, states, x_t, reset_mask, event_budget=None):
    """Single-step convenience form of :func:`batched_lane_window`.

    Returns ``(states, out_spikes [n_lanes, n_classes], emitted
    [n_layers, n_lanes])`` for one tick.  ``event_budget`` routes layer 0
    through the fixed-capacity sparse path, same contract as the window form.
    """
    states, out, emitted = batched_lane_window(
        net, qparams, states, x_t[None], reset_mask, event_budget=event_budget
    )
    return states, out[0], emitted[0]


@functools.partial(jax.jit, static_argnames=("net",))
def _run_int_batched_jit(net, qparams, rasters, lengths):
    T, B, _ = rasters.shape
    states = [int_layer_init(cfg, B) for cfg in net.layers]

    def one_step(states, inp):
        s_t, t = inp
        live = (t < lengths).astype(jnp.int32)  # [B]
        new_states, emitted = [], []
        x = s_t
        for cfg, p, st in zip(net.layers, qparams, states):
            st, x = int_layer_step(cfg, p, st, x)
            new_states.append(st)
            emitted.append(jnp.sum(x, axis=-1) * live)
        return new_states, (x * live[:, None], jnp.stack(emitted, axis=0))

    ts = jnp.arange(T)
    _, (out_spikes, emitted) = jax.lax.scan(one_step, states, (rasters, ts))
    counts = jnp.sum(out_spikes, axis=0)
    live = ts[:, None] < lengths[None, :]  # [T, B]
    input_events = jnp.sum(rasters != 0, axis=-1) * live
    return counts, emitted, input_events


def run_int_batched(net, qparams, rasters, lengths=None, mesh=None) -> SimRecord:
    """One vmap-batched run over a ragged batch of variable-length samples.

    ``rasters`` int [T_max, B, n_in], each sample zero-padded to the longest
    window; ``lengths`` int [B] gives each sample's true window (``None`` =
    all full length).  One jitted scan of :func:`batched_lane_tick`'s step
    advances every sample in lockstep; a sample's contributions (output
    spikes, emitted events, input events) are masked out past its own
    length, so every per-sample slice of the returned :class:`SimRecord` is
    bit-exact with a serial single-sample ``run_int`` over that sample's
    unpadded window (zero-input padding steps could otherwise still fire
    from residual membrane charge).

    This is the whole-window form of the serving seam: the population sweep
    batches *candidates* with one compiled program, this batches *samples*.
    Per-sample record views: ``spike_counts[b]``, ``layer_spikes[l][:Tb, b]``,
    ``input_events[:Tb, b]``.

    ``mesh`` (``None`` | ``"auto"`` | int | ``repro.core.shard.DeviceMesh``)
    spreads the sample axis across devices via ``shard_map`` -- still
    bit-exact per sample (lanes are independent); see ``repro.core.shard``.
    """
    if mesh is not None:
        from repro.core import shard as shard_lib  # deferred: shard imports us

        return shard_lib.run_int_batched_sharded(net, qparams, rasters, lengths, mesh)
    rasters = jnp.asarray(rasters).astype(jnp.int32)
    T, B, _ = rasters.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        if lengths.shape != (B,):
            raise ValueError(f"lengths must be [B]={B}, got {lengths.shape}")
    counts, emitted, input_events = _run_int_batched_jit(
        net, list(qparams), rasters, lengths
    )
    return SimRecord(
        spike_counts=counts,
        layer_spikes=[emitted[:, i, :] for i in range(len(net.layers))],
        input_events=input_events,
    )
