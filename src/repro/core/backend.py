"""Pluggable inference backends for the Flexi-NeurA simulator.

The simulator exposes one seam -- :class:`InferenceBackend` -- through which
every consumer (training eval, the Flex-plorer DSE, serving, benchmarks)
runs a network.  Two backends ship here:

``reference``
    The paper-faithful step-major simulation: one ``jax.lax.scan`` over time
    steps, each step walking every core via ``int_layer_step`` /
    ``float_layer_step``.  This is the numerics contract.

``fused``
    Layer-major traversal that wires the Pallas kernels into the simulator:
    each eligible core's whole window runs as an exact int spike-weight
    matmul (``repro.kernels.quant_matmul.spike_matmul``) feeding the fused
    membrane scan (``repro.kernels.lif_scan``).  Bit-identical to
    ``reference`` by construction (both reduce to ``int_layer_step``'s
    arithmetic); the parity suite in ``tests/test_backend_parity.py`` holds
    it to that.

Fused-path coverage matrix (per layer; ineligible layers transparently run
the reference step scan inside the fused traversal, so mixed networks work):

    neuron     topology   reset              fused kernel path?
    ---------  ---------  -----------------  ----------------------------
    IF / LIF   FF         zero / subtract    yes (matmul + lif_scan)
    IF / LIF   ATA_F/T    any                no  (recurrence couples steps)
    SYNAPTIC   any        any                no  (second state register)

Layer-major traversal is legal because inter-core traffic is strictly
feed-forward and step-aligned (a spike emitted at step t is consumed by the
next core at its step t); only *intra*-layer recurrence couples consecutive
steps, and those layers stay on the step scan.

Adding a backend: subclass :class:`InferenceBackend`, implement ``run_int``
(and optionally ``run_float``), then ``register_backend("name", Factory)``.
Everything above ``network.run_int`` selects backends by name, so new
execution strategies (multi-core mapping, event-driven, remote) plug in
without touching callers.

This module also hosts the population-batched integer simulation used by
the Flex-plorer's population DSE mode: a whole batch of precision
candidates -- same static network structure, different quantized weights,
thresholds and CG decay registers -- runs through one jitted, vmapped
program (``run_int_population``), eliminating the per-candidate
recompile-and-run that dominates serial DSE wall-clock.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.snn_layer import (
    IntLayerParams,
    ResetMode,
    fused_eligible,
    float_layer_init,
    float_layer_step,
    int_layer_init,
    int_layer_step,
    int_layer_step_dynamic,
    int_layer_window,
)
from repro.kernels.lif_scan.lif_scan import lif_scan
from repro.kernels.lif_scan.ref import lif_scan_ref
from repro.kernels.quant_matmul.spike_matmul import spike_integrate

__all__ = [
    "SimRecord",
    "InferenceBackend",
    "ReferenceBackend",
    "FusedBackend",
    "register_backend",
    "get_backend",
    "available_backends",
    "check_population_structure",
    "stack_population",
    "run_int_population",
]


@dataclasses.dataclass
class SimRecord:
    """Outputs of a full-window simulation.

    spike_counts -- [batch, n_classes] output-layer spike totals (rate code)
    layer_spikes -- list over layers of [T, batch] per-step spike totals
                    (events emitted by that layer; feeds the latency model)
    """

    spike_counts: jax.Array
    layer_spikes: list[jax.Array]

    def predictions(self):
        return jnp.argmax(self.spike_counts, axis=-1)


def _run_step_major(net, params, spikes_in, init_fn, step_fn) -> SimRecord:
    """Step-major simulation: scan over time, walk the cores inside."""
    batch = spikes_in.shape[1]
    states = [init_fn(cfg, batch) for cfg in net.layers]

    def one_step(states, s_t):
        new_states = []
        x = s_t
        emitted = []
        for cfg, p, st in zip(net.layers, params, states):
            st, x = step_fn(cfg, p, st, x)
            new_states.append(st)
            emitted.append(jnp.sum(x, axis=-1))  # events per sample this step
        return new_states, (x, jnp.stack(emitted, axis=0))

    states, (out_spikes, emitted) = jax.lax.scan(one_step, states, spikes_in)
    counts = jnp.sum(out_spikes, axis=0)
    layer_spikes = [emitted[:, i, :] for i in range(len(net.layers))]
    return SimRecord(spike_counts=counts, layer_spikes=layer_spikes)


class InferenceBackend:
    """One execution strategy for a full-window network simulation."""

    name = "base"

    def run_int(self, net, qparams: Sequence[IntLayerParams], spikes_in) -> SimRecord:
        raise NotImplementedError

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        raise NotImplementedError


class ReferenceBackend(InferenceBackend):
    """Step-major jnp semantics -- the numerics contract for every backend."""

    name = "reference"

    def run_int(self, net, qparams, spikes_in) -> SimRecord:
        return _run_step_major(
            net, list(qparams), spikes_in.astype(jnp.int32), int_layer_init, int_layer_step
        )

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        def step(cfg, p, st, x):
            return float_layer_step(cfg, p, st, x, spike_fn)

        return _run_step_major(
            net, list(params), spikes_in.astype(jnp.float32), float_layer_init, step
        )


class FusedBackend(InferenceBackend):
    """Layer-major traversal through the fused integration + membrane kernels.

    ``use_pallas`` selects the Pallas kernels (default: only on TPU; the
    pure-jnp window oracle carries the identical numerics elsewhere, which
    keeps CPU/GPU runs fast -- interpret-mode Pallas is a debugging tool,
    not a fast path).  ``interpret`` forces interpreter execution of the
    kernels off-TPU; the parity suite uses ``use_pallas=True,
    interpret=True`` to hold the *actual kernels* to the bit-exact contract
    on CPU.
    """

    name = "fused"

    def __init__(
        self,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        block_b: int = 8,
        block_n: int = 128,
    ):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block_b = block_b
        self.block_n = block_n

    def _pallas_enabled(self) -> bool:
        if self.use_pallas is None:
            return jax.default_backend() == "tpu"
        return self.use_pallas

    def _interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def _fused_layer_window(self, cfg, p: IntLayerParams, raster):
        """Whole-window spikes for one FF IF/LIF core via the kernel pair."""
        use_pallas = self._pallas_enabled()
        currents = spike_integrate(
            raster, p.w_ff, use_pallas=use_pallas, interpret=self._interpret()
        )
        code = cfg.beta_code()
        decay_k = 256 if code.bypass else code.k
        reset_to_zero = cfg.reset == ResetMode.ZERO
        try:
            theta_q = int(p.theta_q)  # static for the Pallas kernel
        except (
            jax.errors.TracerIntegerConversionError,
            jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError,
        ):
            theta_q = None  # traced weights (e.g. under vmap): oracle only
        T, B, N = currents.shape
        bb, bn = min(self.block_b, B), min(self.block_n, N)
        if theta_q is None or not use_pallas or B % bb or N % bn:
            theta = p.theta_q if theta_q is None else theta_q
            spikes, _ = lif_scan_ref(currents, theta, decay_k, cfg.u_bits, reset_to_zero)
            return spikes
        spikes, _ = lif_scan(
            currents,
            theta_q=theta_q,
            decay_k=decay_k,
            u_bits=cfg.u_bits,
            reset_to_zero=reset_to_zero,
            block_b=bb,
            block_n=bn,
            interpret=self._interpret(),
        )
        return spikes

    def run_int(self, net, qparams, spikes_in) -> SimRecord:
        x = spikes_in.astype(jnp.int32)
        emitted = []
        for cfg, p in zip(net.layers, qparams):
            if fused_eligible(cfg):
                x = self._fused_layer_window(cfg, p, x)
            else:
                x = int_layer_window(cfg, p, x)
            emitted.append(jnp.sum(x, axis=-1))  # [T, batch]
        counts = jnp.sum(x, axis=0)
        return SimRecord(spike_counts=counts, layer_spikes=emitted)

    def run_float(self, net, params, spikes_in, spike_fn) -> SimRecord:
        # The fused kernels are integer-only; float (training) simulation
        # keeps the differentiable reference semantics.
        return ReferenceBackend().run_float(net, params, spikes_in, spike_fn)


_REGISTRY: dict[str, Callable[[], InferenceBackend]] = {}


def register_backend(name: str, factory: Callable[[], InferenceBackend]) -> None:
    """Register a backend factory under ``name`` (later wins, like a config)."""
    _REGISTRY[name] = factory


def get_backend(backend: str | InferenceBackend) -> InferenceBackend:
    """Resolve a backend selector: a registered name or an instance."""
    if isinstance(backend, InferenceBackend):
        return backend
    try:
        return _REGISTRY[backend]()
    except KeyError:
        raise ValueError(
            f"unknown inference backend {backend!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


register_backend("reference", ReferenceBackend)
register_backend("fused", FusedBackend)


# ---------------------------------------------------------------------------
# Population-batched integer simulation (the Flex-plorer DSE hot path)
# ---------------------------------------------------------------------------


# Layer fields a population sweep may vary per candidate: they only reach the
# traced program through quantized values / decay registers.  Everything else
# is static (baked into the one compiled program) and must match the base net.
_POPULATION_KNOBS = ("w_bits", "w_rec_bits", "leak_bits", "beta", "alpha")


def check_population_structure(base, nets) -> None:
    """Raise unless every candidate shares ``base``'s static structure."""
    base_sig = [
        {f.name: getattr(lc, f.name) for f in dataclasses.fields(lc) if f.name not in _POPULATION_KNOBS}
        for lc in base.layers
    ]
    for net in nets:
        if len(net.layers) != len(base.layers):
            raise ValueError(
                f"population candidate {net.name!r} has {len(net.layers)} layers, base has {len(base.layers)}"
            )
        for i, lc in enumerate(net.layers):
            for name, want in base_sig[i].items():
                got = getattr(lc, name)
                if got != want:
                    raise ValueError(
                        f"population candidate {net.name!r} layer {i} differs from the "
                        f"base net in static field {name!r} ({got!r} != {want!r}); only "
                        f"{_POPULATION_KNOBS} may vary across a population sweep"
                    )


def stack_population(nets, qparams_list):
    """Stack per-candidate quantized parameters for a vmapped evaluation.

    ``nets`` are per-candidate :class:`NetworkConfig`s sharing one static
    structure (layer count/shapes/neuron/topology/reset/register widths --
    exactly what the DSE holds fixed while varying ``w_bits`` /
    ``w_rec_bits`` / ``leak_bits``); ``qparams_list`` the matching
    ``quantize_params`` outputs.  Returns ``(stacked_qparams, beta_regs,
    alpha_regs)`` where each stacked leaf gains a leading candidate axis and
    the decay registers are int32 ``[P, n_layers]`` packed DecayRate values.
    """
    n_layers = len(nets[0].layers)
    stacked = [
        IntLayerParams(
            w_ff=jnp.stack([qp[l].w_ff for qp in qparams_list]),
            w_rec=jnp.stack([qp[l].w_rec for qp in qparams_list]),
            theta_q=jnp.stack([qp[l].theta_q for qp in qparams_list]),
        )
        for l in range(n_layers)
    ]
    beta_regs = jnp.asarray(
        [[cfg.beta_code().decay_rate_register for cfg in net.layers] for net in nets],
        jnp.int32,
    )
    alpha_regs = jnp.asarray(
        [[cfg.alpha_code().decay_rate_register for cfg in net.layers] for net in nets],
        jnp.int32,
    )
    return stacked, beta_regs, alpha_regs


def _run_int_dynamic(net, qparams, beta_regs, alpha_regs, spikes_in):
    """One candidate's bit-exact run with traced decay registers.

    Numerically identical to ``ReferenceBackend.run_int`` (the dynamic step
    gates the same shift taps arithmetically); exists so the decay registers
    can differ across vmapped candidates.
    """
    batch = spikes_in.shape[1]
    states = [int_layer_init(cfg, batch) for cfg in net.layers]

    def one_step(states, s_t):
        new_states = []
        x = s_t
        for i, (cfg, p, st) in enumerate(zip(net.layers, qparams, states)):
            st, x = int_layer_step_dynamic(cfg, p, st, x, beta_regs[i], alpha_regs[i])
            new_states.append(st)
        return new_states, x

    _, out_spikes = jax.lax.scan(one_step, states, spikes_in)
    return jnp.sum(out_spikes, axis=0)  # [batch, n_classes]


def run_int_population(net, stacked_qparams, beta_regs, alpha_regs, spikes_in):
    """Score P precision candidates in one vmapped sweep.

    ``spikes_in`` int [T, batch, n_in] is shared by all candidates (the DSE
    evaluates every candidate on the same held-out batch).  Returns int32
    spike counts [P, batch, n_classes].
    """
    spikes_in = spikes_in.astype(jnp.int32)

    def one(qp, beta, alpha):
        return _run_int_dynamic(net, qp, beta, alpha, spikes_in)

    return jax.vmap(one, in_axes=(0, 0, 0))(stacked_qparams, beta_regs, alpha_regs)
