"""Fixed-point arithmetic contracts shared by the bit-exact simulator and kernels.

Flexi-NeurA stores every on-chip quantity as a signed fixed-point integer whose
bit-width is a design-time parameter:

* synaptic weights           -- ``w_bits``  (feed-forward) / ``w_rec_bits`` (recurrent)
* membrane potential ``U``   -- ``u_bits``
* synaptic current ``I_syn`` -- ``i_bits``

Thresholds and reset values are *automatically rescaled* to the selected
precision (paper section 4): the float threshold theta is mapped through the same
scale as the weights so that the integer comparison ``U >= theta_q`` is
equivalent to the float comparison up to quantization error.

All integer arithmetic here is performed in int32 with explicit saturation to
the declared register width; this mirrors a saturating hardware accumulator
and keeps the simulator's numerics well-defined for any bit-width <= 24.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantSpec",
    "quantize_symmetric",
    "dequantize",
    "int_min",
    "int_max",
    "saturate",
    "sat_add",
    "arithmetic_rshift",
]


def int_min(bits: int) -> int:
    """Smallest representable signed integer at ``bits`` width."""
    return -(1 << (bits - 1))


def int_max(bits: int) -> int:
    """Largest representable signed integer at ``bits`` width."""
    return (1 << (bits - 1)) - 1


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Symmetric signed fixed-point quantization spec.

    ``scale`` maps float -> integer: ``q = clip(round(x * scale))``.
    The same scale is applied to thresholds/resets so integer dynamics mirror
    the float dynamics (paper: "Threshold and reset values are automatically
    rescaled to match the selected precision").
    """

    bits: int
    scale: float

    @property
    def qmin(self) -> int:
        return int_min(self.bits)

    @property
    def qmax(self) -> int:
        return int_max(self.bits)

    def quantize(self, x):
        return quantize_symmetric(x, self.bits, self.scale)

    def dequantize(self, q):
        return dequantize(q, self.scale)


def make_spec_from_absmax(x, bits: int, margin: float = 1.0) -> QuantSpec:
    """Build a QuantSpec so that ``margin * max|x|`` maps to the integer max."""
    absmax = float(np.max(np.abs(np.asarray(x)))) if np.size(np.asarray(x)) else 1.0
    absmax = max(absmax * margin, 1e-12)
    return QuantSpec(bits=bits, scale=int_max(bits) / absmax)


def quantize_symmetric(x, bits: int, scale: float):
    """Round-to-nearest-even symmetric quantization with clipping."""
    q = jnp.round(jnp.asarray(x, jnp.float32) * scale)
    return jnp.clip(q, int_min(bits), int_max(bits)).astype(jnp.int32)


def dequantize(q, scale: float):
    return jnp.asarray(q, jnp.float32) / scale


def saturate(x, bits: int):
    """Clamp an int32 value into the signed ``bits``-wide register range."""
    return jnp.clip(x, int_min(bits), int_max(bits))


def sat_add(a, b, bits: int):
    """Saturating signed add: models the hardware accumulator at ``bits`` width.

    Inputs are int32 whose magnitudes fit well inside int32 (bits <= 24), so
    the int32 addition itself never wraps; only the register clamp applies.
    """
    return saturate(a + b, bits)


def arithmetic_rshift(x, n: int):
    """Arithmetic shift right on int32 (floor division by 2**n), as in RTL.

    jnp's ``>>`` on signed ints is an arithmetic shift; kept as a named helper
    so the simulator/kernels/oracle all share one definition.
    """
    return jnp.asarray(x, jnp.int32) >> n
