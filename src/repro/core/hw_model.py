"""Analytical hardware models: resources, latency, power, energy.

The paper's Flex-plorer uses (i) regressions over post-synthesis LUT/FF
measurements, (ii) a parametric BRAM model derived from the memory
organisation rules of section 4.1.1, and (iii) a cycle model (60 MHz clock,
~100-cycle controller loop, per-neuron sequential updates) for latency.
No synthesis tool exists in this container, so the models here are built
directly from the paper's published rules and anchored, exactly, to its
reported MNIST design point:

    256-128-10, LIF, FF topology, 6-bit weights, 8-bit neuron state,
    2 cores  ->  934 LUT, 689 FF, 7 BRAM, 1 623 logic cells (= LUT + FF),
    1.1 ms / image @ 60 MHz, 111 mW, 0.12 mJ / image.

Anchoring rules (each free constant is *solved*, not tuned, so the paper's
design point reproduces exactly and a regression test can hold it):

* LUT/FF: per-bit datapath slopes are fixed interpretations; the per-core
  controller/SPI/AMU bases are solved from the 934/689 totals
  (``_solve_bases``).
* Latency: the cycle model is fully determined by event counts (the paper's
  pipeline is event-driven -- cycles scale with ASPL/ASCL traffic, not with
  dense layer size); the anchor *operating point* -- the mean input event
  rate the paper's deployment must have seen -- is solved from the 1.1 ms
  figure (``_solve_anchor_input_rate``), with the hidden/output rates set to
  representative sparse-traffic constants.
* Energy: static + per-resource dynamic power are fixed; the switching
  energy per synaptic event is solved from the 0.12 mJ figure at the anchor
  traffic (``_solve_event_switching_power``).

Latency and energy are therefore functions of *measured event traffic*
(:class:`EventTraffic`, built from any backend's ``SimRecord`` or from
``eval_int(..., return_stats=True)``), which is what lets the Flex-plorer
anneal against realistic event-dependent latency instead of worst-case
dense cycles.  These models are *the cost functions the DSE anneals
against* -- precisely the role they play in the paper.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.network import NetworkConfig
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology

__all__ = [
    "bram36_count",
    "CoreResources",
    "core_resources",
    "network_resources",
    "EventTraffic",
    "paper_mnist_traffic",
    "latency_seconds",
    "power_watts",
    "energy_per_image",
    "BandwidthProfile",
    "bandwidth_profile",
    "DesignPoint",
    "design_point",
]

# --------------------------------------------------------------------------
# Memory organisation (paper section 4.1.1)
# --------------------------------------------------------------------------

#: Xilinx 7-series BRAM36 aspect ratios (depth, width).
_BRAM36_ASPECTS = ((32768, 1), (16384, 2), (8192, 4), (4096, 9), (2048, 18), (1024, 36), (512, 72))

#: Memories at or below this bit count map to distributed LUTRAM, not BRAM.
_LUTRAM_THRESHOLD_BITS = 4096
_LUTRAM_BITS_PER_LUT = 64  # RAM64X1S


def _ceil_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, n))))


def bram36_count(depth: int, width: int) -> int:
    """Minimum BRAM36 tiles for a depth x width RAM over the legal aspects."""
    return min(
        math.ceil(depth / d) * math.ceil(width / w) for d, w in _BRAM36_ASPECTS
    )


def _synaptic_memory_dims(n_src: int, n_dst: int, w_bits: int) -> tuple[int, int]:
    """(depth, width) after the paper's three-level rounding rules."""
    blocks = _ceil_pow2(n_src)
    rows_per_block = _ceil_pow2(math.ceil(n_dst / 8))
    width = 8 * w_bits
    return blocks * rows_per_block, width


def _neuron_state_dims(cfg: LayerConfig) -> tuple[int, int]:
    state_bits = cfg.u_bits + (cfg.i_bits if cfg.neuron == NeuronModel.SYNAPTIC else 0)
    width = 8 * math.ceil(state_bits / 8)  # byte-boundary rounding
    depth = _ceil_pow2(cfg.n_out)
    return depth, width


# --------------------------------------------------------------------------
# LUT / FF datapath model (regression form, anchored to the paper's design)
# --------------------------------------------------------------------------

# Per-core linear coefficients. Interpretations: weight-datapath slices per
# weight bit, membrane ALU slices per state bit, CG adder slices per shift
# tap, plus a fixed controller+SPI+AMU base solved from the anchor below.
_LUT_PER_W_BIT = 18.0
_LUT_PER_U_BIT = 22.0
_LUT_PER_I_BIT = 14.0
_LUT_PER_RECW_BIT = 12.0
_LUT_PER_CG_TAP = 8.0

_FF_PER_W_BIT = 8.0
_FF_PER_U_BIT = 14.0
_FF_PER_I_BIT = 9.0
_FF_PER_RECW_BIT = 6.0
_FF_PER_CG_TAP = 4.0

# Anchor: 2 identical-shape FF/LIF cores (w=6, u=8, 8 CG taps) total
# 934 LUT / 689 FF *including* LUTRAM-mapped neuron-state memories.
_ANCHOR_LUT_TOTAL = 934.0
_ANCHOR_FF_TOTAL = 689.0


def _anchor_cores() -> list[LayerConfig]:
    return [
        LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=8),
        LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=8),
    ]


def _variable_lut(cfg: LayerConfig) -> float:
    lut = _LUT_PER_W_BIT * cfg.w_bits + _LUT_PER_U_BIT * cfg.u_bits
    if cfg.neuron == NeuronModel.SYNAPTIC:
        lut += _LUT_PER_I_BIT * cfg.i_bits
    if cfg.topology == Topology.ATA_T:
        lut += _LUT_PER_RECW_BIT * cfg.w_rec_bits
    lut += _LUT_PER_CG_TAP * cfg.leak_bits
    return lut


def _variable_ff(cfg: LayerConfig) -> float:
    ff = _FF_PER_W_BIT * cfg.w_bits + _FF_PER_U_BIT * cfg.u_bits
    if cfg.neuron == NeuronModel.SYNAPTIC:
        ff += _FF_PER_I_BIT * cfg.i_bits
    if cfg.topology == Topology.ATA_T:
        ff += _FF_PER_RECW_BIT * cfg.w_rec_bits
    ff += _FF_PER_CG_TAP * cfg.leak_bits
    return ff


def _lutram_luts(cfg: LayerConfig) -> float:
    """LUTs consumed by memories small enough to map to distributed RAM."""
    total = 0.0
    for depth, width in _memory_list(cfg):
        bits = depth * width
        if bits <= _LUTRAM_THRESHOLD_BITS:
            total += bits / _LUTRAM_BITS_PER_LUT
    return total


def _memory_list(cfg: LayerConfig) -> list[tuple[int, int]]:
    mems = [_synaptic_memory_dims(cfg.n_in, cfg.n_out, cfg.w_bits)]
    if cfg.topology == Topology.ATA_T:
        mems.append(_synaptic_memory_dims(cfg.n_out, cfg.n_out, cfg.w_rec_bits))
    mems.append(_neuron_state_dims(cfg))
    return mems


def _solve_bases() -> tuple[float, float]:
    cores = _anchor_cores()
    var_lut = sum(_variable_lut(c) + _lutram_luts(c) for c in cores)
    var_ff = sum(_variable_ff(c) for c in cores)
    base_lut = (_ANCHOR_LUT_TOTAL - var_lut) / len(cores)
    base_ff = (_ANCHOR_FF_TOTAL - var_ff) / len(cores)
    return base_lut, base_ff


_BASE_LUT, _BASE_FF = _solve_bases()


@dataclasses.dataclass(frozen=True)
class CoreResources:
    lut: float
    ff: float
    bram: int

    @property
    def logic_cells(self) -> float:
        return self.lut + self.ff

    def __add__(self, other: "CoreResources") -> "CoreResources":
        return CoreResources(self.lut + other.lut, self.ff + other.ff, self.bram + other.bram)


def core_resources(cfg: LayerConfig) -> CoreResources:
    lut = _BASE_LUT + _variable_lut(cfg) + _lutram_luts(cfg)
    ff = _BASE_FF + _variable_ff(cfg)
    bram = 0
    for depth, width in _memory_list(cfg):
        if depth * width > _LUTRAM_THRESHOLD_BITS:
            bram += bram36_count(depth, width)
    return CoreResources(lut=lut, ff=ff, bram=bram)


@functools.lru_cache(maxsize=1024)
def network_resources(net: NetworkConfig) -> CoreResources:
    # cached: configs are frozen/hashable, and the serving engine evaluates a
    # design point per completed request against one fixed network
    total = CoreResources(0.0, 0.0, 0)
    for cfg in net.layers:
        total = total + core_resources(cfg)
    return total


# --------------------------------------------------------------------------
# Measured event traffic (what the latency / energy models consume)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EventTraffic:
    """Mean per-step event counts of one deployment: the cost-model input.

    ``input_events_per_step`` -- [T] mean ASPL count into layer 0;
    ``layer_events_per_step`` -- per layer, [T] mean spikes *emitted* (layer
    l's entry is consumed by layer l+1, and by layer l itself on the
    recurrent path at step t+1).  Build one from a simulation via
    :meth:`from_record` / :meth:`from_stats`, or synthesize a constant-rate
    operating point via :meth:`constant_rate`.
    """

    input_events_per_step: np.ndarray
    layer_events_per_step: tuple[np.ndarray, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "input_events_per_step", np.asarray(self.input_events_per_step, np.float64)
        )
        object.__setattr__(
            self,
            "layer_events_per_step",
            tuple(np.asarray(e, np.float64) for e in self.layer_events_per_step),
        )
        T = len(self.input_events_per_step)
        for e in self.layer_events_per_step:
            if len(e) != T:
                raise ValueError(f"layer event series length {len(e)} != window {T}")

    @classmethod
    def from_record(cls, record) -> "EventTraffic":
        """Batch-mean traffic from any backend's ``SimRecord``."""
        stats = record.event_stats()
        return cls.from_stats(stats)

    @classmethod
    def from_stats(cls, stats: dict) -> "EventTraffic":
        """From the dict shape of ``eval_int(..., return_stats=True)``."""
        return cls(
            input_events_per_step=stats["input_events_per_step"],
            layer_events_per_step=tuple(stats["layer_events_per_step"]),
        )

    @classmethod
    def constant_rate(
        cls, T: int, input_rate: float, layer_rates: tuple[float, ...]
    ) -> "EventTraffic":
        return cls(
            input_events_per_step=np.full(T, float(input_rate)),
            layer_events_per_step=tuple(np.full(T, float(r)) for r in layer_rates),
        )

    @property
    def n_steps(self) -> int:
        return len(self.input_events_per_step)

    @property
    def total_events_per_image(self) -> float:
        """All events of one sample: input ASPLs + every layer's emissions."""
        return float(
            self.input_events_per_step.sum()
            + sum(e.sum() for e in self.layer_events_per_step)
        )


# --------------------------------------------------------------------------
# Latency model (60 MHz, pipelined cores, per-neuron sequential sweeps)
# --------------------------------------------------------------------------

CLOCK_HZ = 60e6
_CONTROLLER_OVERHEAD_CYCLES = 100  # per step per core (paper's controller loop)


def step_cycles(cfg: LayerConfig, n_in_events: float, n_rec_events: float) -> float:
    """Cycles one core spends on one time step.

    FF-Integ sweeps all n_out neurons per incoming ASPL; REC-Integ sweeps
    n_out per ASCL under ATA-T but only the source neuron under ATA-F; the
    Leak/Spike phase visits every neuron once.
    """
    cycles = n_in_events * cfg.n_out
    if cfg.topology == Topology.ATA_T:
        cycles += n_rec_events * cfg.n_out
    elif cfg.topology == Topology.ATA_F:
        cycles += n_rec_events
    cycles += cfg.n_out  # leak / spike-generation sweep
    return cycles + _CONTROLLER_OVERHEAD_CYCLES


def latency_seconds(
    net: NetworkConfig,
    traffic,  # EventTraffic, or legacy [T] input-event array
    layer_events_per_step=None,  # legacy: per layer, [T] mean emitted spikes
) -> float:
    """End-to-end latency of one sample through the pipelined multi-core system.

    ``traffic`` is an :class:`EventTraffic` (preferred -- build one from any
    backend's ``SimRecord`` or from ``eval_int`` stats); the legacy two-array
    form ``latency_seconds(net, input_events, layer_events)`` is still
    accepted.  Cores overlap across time steps (layer L works on step t
    while L+1 works on step t-1), so the steady-state cost of a step is the
    *maximum* over cores, plus a pipeline fill of one step per extra core.
    """
    if not isinstance(traffic, EventTraffic):
        traffic = EventTraffic(
            input_events_per_step=traffic,
            layer_events_per_step=tuple(layer_events_per_step),
        )
    T = traffic.n_steps
    per_core_step_cycles = np.zeros((len(net.layers), T))
    for li, cfg in enumerate(net.layers):
        in_ev = (
            traffic.input_events_per_step
            if li == 0
            else traffic.layer_events_per_step[li - 1]
        )
        # Recurrent events consumed at step t are the spikes of step t-1
        # (vectorised form of ``step_cycles`` over the window; identical
        # arithmetic, held together by test_snn_core's latency tests).
        rec_ev = np.zeros(T)
        if cfg.is_recurrent:
            rec_ev[1:] = traffic.layer_events_per_step[li][:-1]
        cycles = in_ev * cfg.n_out
        if cfg.topology == Topology.ATA_T:
            cycles = cycles + rec_ev * cfg.n_out
        elif cfg.topology == Topology.ATA_F:
            cycles = cycles + rec_ev
        per_core_step_cycles[li] = cycles + cfg.n_out + _CONTROLLER_OVERHEAD_CYCLES
    steady = per_core_step_cycles.max(axis=0).sum()
    fill = sum(
        per_core_step_cycles[li, 0] for li in range(len(net.layers) - 1)
    )  # drain of the first step through earlier cores
    return float(steady + fill) / CLOCK_HZ


# --------------------------------------------------------------------------
# Memory-bandwidth bottleneck model (after arxiv 2511.21549)
# --------------------------------------------------------------------------

# The event-driven datapath's external-memory traffic per core per step:
# every incoming ASPL fetches the full n_out-wide synaptic weight row
# (FF-Integ), recurrent ASCLs fetch n_out weights under ATA-T but a single
# source weight under ATA-F (REC-Integ), and the Leak/Spike sweep reads and
# writes every neuron's packed state word once.  This mirrors the cycle
# model above -- cycles and bytes both scale with measured event traffic --
# which is exactly the bottleneck-modeling observation: for neuromorphic
# accelerators the limiting resource at deployment is usually the memory
# system, and it must be modeled from *traffic*, not peak compute.


@dataclasses.dataclass(frozen=True)
class BandwidthProfile:
    """Per-layer memory-traffic demand of one deployment at measured traffic.

    ``layer_bytes_per_image`` -- external-memory bytes each core moves per
    sample (weight rows + neuron-state read/write); ``duration_s`` -- the
    pipelined per-sample latency the traffic is sustained over;
    ``layer_demand_bytes_s`` / ``demand_bytes_s`` -- per-core and total
    sustained bandwidth demand.  :meth:`congestion` turns the total into
    the Flex-plorer's dimensionless penalty: 0 while demand fits the
    device's sustainable bandwidth, else the fractional overshoot.
    """

    layer_bytes_per_image: tuple[float, ...]
    duration_s: float

    @property
    def total_bytes_per_image(self) -> float:
        return float(sum(self.layer_bytes_per_image))

    @property
    def layer_demand_bytes_s(self) -> tuple[float, ...]:
        if self.duration_s <= 0:
            return tuple(0.0 for _ in self.layer_bytes_per_image)
        return tuple(b / self.duration_s for b in self.layer_bytes_per_image)

    @property
    def demand_bytes_s(self) -> float:
        return float(sum(self.layer_demand_bytes_s))

    def congestion(self, capacity_bytes_s: float) -> float:
        """max(0, demand/capacity - 1): how far past the memory system the
        design's sustained traffic runs (0 = uncongested)."""
        if capacity_bytes_s <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity_bytes_s}")
        return max(0.0, self.demand_bytes_s / capacity_bytes_s - 1.0)


def _layer_state_bytes(cfg: LayerConfig) -> float:
    """Bytes of one neuron's packed state word (byte-boundary rounded)."""
    _, width_bits = _neuron_state_dims(cfg)
    return width_bits / 8.0


def bandwidth_profile(net: NetworkConfig, traffic: EventTraffic) -> BandwidthProfile:
    """Memory-traffic demand of ``net`` at measured event traffic."""
    T = traffic.n_steps
    layer_bytes: list[float] = []
    for li, cfg in enumerate(net.layers):
        in_ev = (
            traffic.input_events_per_step
            if li == 0
            else traffic.layer_events_per_step[li - 1]
        )
        rec_ev = np.zeros(T)
        if cfg.is_recurrent:
            rec_ev[1:] = traffic.layer_events_per_step[li][:-1]
        # FF-Integ: one n_out-wide weight row per incoming ASPL
        bytes_per_step = in_ev * (cfg.n_out * cfg.w_bits / 8.0)
        # REC-Integ: full row under ATA-T, single source weight under ATA-F
        if cfg.topology == Topology.ATA_T:
            bytes_per_step = bytes_per_step + rec_ev * (cfg.n_out * cfg.w_rec_bits / 8.0)
        elif cfg.topology == Topology.ATA_F:
            bytes_per_step = bytes_per_step + rec_ev * (cfg.w_rec_bits / 8.0)
        # Leak/Spike: read + write every neuron's state word once per step
        bytes_per_step = bytes_per_step + 2.0 * cfg.n_out * _layer_state_bytes(cfg)
        layer_bytes.append(float(bytes_per_step.sum()))
    return BandwidthProfile(
        layer_bytes_per_image=tuple(layer_bytes),
        duration_s=latency_seconds(net, traffic),
    )


# --------------------------------------------------------------------------
# The paper's MNIST operating point (solved from the published 1.1 ms)
# --------------------------------------------------------------------------

_PAPER_T = 100  # the paper's MNIST inference window
_ANCHOR_LATENCY_S = 1.1e-3
_ANCHOR_ENERGY_J = 0.12e-3
# Representative sparse traffic of the trained network's deeper cores (the
# hidden core emits a few spikes per step; the rate-coded output emits ~1).
# Only the *input* rate materially moves the cycle model (core 0 dominates),
# so it is the one solved from the published latency.
_ANCHOR_HIDDEN_EVENTS_PER_STEP = 6.0
_ANCHOR_OUTPUT_EVENTS_PER_STEP = 1.0


def _paper_anchor_net() -> NetworkConfig:
    return NetworkConfig(
        layers=tuple(_anchor_cores()), n_steps=_PAPER_T, name="mnist-paper-anchor"
    )


def _solve_anchor_input_rate() -> float:
    """Mean input events/step implied by the paper's 1.1 ms at 60 MHz.

    With constant rates, core 0 dominates every steady-state step and the
    pipeline adds one extra core-0 step of fill, so

        (T + 1) * (x * n_out + n_out + overhead) = latency * f_clk.

    Solving for x pins the model to the published figure the same way
    ``_solve_bases`` pins LUT/FF -- the anchor is reproduced *exactly* by
    construction, and a regression test holds it.
    """
    net = _paper_anchor_net()
    core0 = net.layers[0]
    total_cycles = _ANCHOR_LATENCY_S * CLOCK_HZ
    per_step = total_cycles / (_PAPER_T + 1)
    x = (per_step - core0.n_out - _CONTROLLER_OVERHEAD_CYCLES) / core0.n_out
    # the solution is only consistent if core 0 really dominates core 1
    core1_cycles = step_cycles(net.layers[1], _ANCHOR_HIDDEN_EVENTS_PER_STEP, 0.0)
    if x <= 0 or per_step <= core1_cycles:
        raise RuntimeError(
            "latency anchor solve inconsistent: core 0 must dominate the "
            f"steady state (input rate {x:.3f}, per-step budget {per_step:.1f} "
            f"vs core-1 {core1_cycles:.1f} cycles); check the anchor constants"
        )
    return x


PAPER_MNIST_INPUT_EVENTS_PER_STEP = _solve_anchor_input_rate()


def paper_mnist_traffic() -> EventTraffic:
    """The anchor operating point: the event traffic at which the cycle and
    energy models reproduce the paper's 1.1 ms / 0.12 mJ exactly."""
    return EventTraffic.constant_rate(
        _PAPER_T,
        PAPER_MNIST_INPUT_EVENTS_PER_STEP,
        (_ANCHOR_HIDDEN_EVENTS_PER_STEP, _ANCHOR_OUTPUT_EVENTS_PER_STEP),
    )


# --------------------------------------------------------------------------
# Power / energy model
# --------------------------------------------------------------------------

# Zynq-7020-class static power plus dynamic terms per resource; the paper's
# MNIST point reports 111 mW total ("dominated by static power").
STATIC_WATTS = 0.095
_DYN_W_PER_LUT = 4.0e-6
_DYN_W_PER_BRAM = 1.0e-3


def _solve_event_switching_power() -> float:
    """Watts per million synaptic events/s, solved from the 0.12 mJ anchor.

    At the anchor operating point the total power must equal
    0.12 mJ / 1.1 ms; static + resource-dynamic power is fixed by the
    resource model, so the residual is the event-switching term.
    """
    net = _paper_anchor_net()
    res = network_resources(net)
    base = STATIC_WATTS + _DYN_W_PER_LUT * res.logic_cells + _DYN_W_PER_BRAM * res.bram
    target_power = _ANCHOR_ENERGY_J / _ANCHOR_LATENCY_S
    meps = paper_mnist_traffic().total_events_per_image / _ANCHOR_LATENCY_S / 1e6
    w = (target_power - base) / meps
    if w <= 0:
        raise RuntimeError(
            "energy anchor solve inconsistent: static+resource power "
            f"({base:.4f} W) must sit below the 0.12 mJ / 1.1 ms anchor power "
            f"({target_power:.4f} W); check STATIC_WATTS / _DYN_W_PER_*"
        )
    return w


_DYN_W_PER_MEVENT_S = _solve_event_switching_power()


def power_watts(net: NetworkConfig, events_per_second: float = 0.0) -> float:
    res = network_resources(net)
    dyn = (
        _DYN_W_PER_LUT * res.logic_cells
        + _DYN_W_PER_BRAM * res.bram
        + _DYN_W_PER_MEVENT_S * events_per_second / 1e6
    )
    return STATIC_WATTS + dyn


def energy_per_image(net: NetworkConfig, latency_s: float, events_per_image) -> float:
    """Energy of one sample; ``events_per_image`` is a float total or an
    :class:`EventTraffic` (its per-image event total is used)."""
    if isinstance(events_per_image, EventTraffic):
        events_per_image = events_per_image.total_events_per_image
    eps = events_per_image / latency_s if latency_s > 0 else 0.0
    return power_watts(net, eps) * latency_s


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One deployment's modeled operating figures at measured traffic.

    ``bw_demand_bytes_s`` is the sustained external-memory bandwidth the
    design draws at this traffic (0.0 for design points built before the
    bottleneck model existed -- old serialized artifacts still load).
    """

    latency_s: float
    power_w: float
    energy_per_image_j: float
    events_per_image: float
    bw_demand_bytes_s: float = 0.0


def design_point(net: NetworkConfig, traffic: EventTraffic) -> DesignPoint:
    """Latency / power / energy / bandwidth of ``net`` at measured event
    traffic -- the event-aware summary the Flex-plorer's perf cost term
    anneals against."""
    lat = latency_seconds(net, traffic)
    events = traffic.total_events_per_image
    bw = bandwidth_profile(net, traffic)
    return DesignPoint(
        latency_s=lat,
        power_w=power_watts(net, events / lat if lat > 0 else 0.0),
        energy_per_image_j=energy_per_image(net, lat, events),
        events_per_image=events,
        bw_demand_bytes_s=bw.demand_bytes_s,
    )
