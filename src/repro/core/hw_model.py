"""Analytical hardware models: resources, latency, power, energy.

The paper's Flex-plorer uses (i) regressions over post-synthesis LUT/FF
measurements, (ii) a parametric BRAM model derived from the memory
organisation rules of section 4.1.1, and (iii) a cycle model (60 MHz clock,
~100-cycle controller loop, per-neuron sequential updates) for latency.
No synthesis tool exists in this container, so the models here are built
directly from the paper's published rules and anchored, exactly, to its
reported MNIST design point:

    256-128-10, LIF, FF topology, 6-bit weights, 8-bit neuron state,
    2 cores  ->  934 LUT, 689 FF, 7 BRAM, 1 623 logic cells (= LUT + FF),
    1.1 ms / image @ 60 MHz, 111 mW, 0.12 mJ / image.

These models are *the cost functions the DSE anneals against* -- precisely
the role they play in the paper.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.network import NetworkConfig
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology

__all__ = [
    "bram36_count",
    "CoreResources",
    "core_resources",
    "network_resources",
    "latency_seconds",
    "power_watts",
    "energy_per_image",
]

# --------------------------------------------------------------------------
# Memory organisation (paper section 4.1.1)
# --------------------------------------------------------------------------

#: Xilinx 7-series BRAM36 aspect ratios (depth, width).
_BRAM36_ASPECTS = ((32768, 1), (16384, 2), (8192, 4), (4096, 9), (2048, 18), (1024, 36), (512, 72))

#: Memories at or below this bit count map to distributed LUTRAM, not BRAM.
_LUTRAM_THRESHOLD_BITS = 4096
_LUTRAM_BITS_PER_LUT = 64  # RAM64X1S


def _ceil_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, n))))


def bram36_count(depth: int, width: int) -> int:
    """Minimum BRAM36 tiles for a depth x width RAM over the legal aspects."""
    return min(
        math.ceil(depth / d) * math.ceil(width / w) for d, w in _BRAM36_ASPECTS
    )


def _synaptic_memory_dims(n_src: int, n_dst: int, w_bits: int) -> tuple[int, int]:
    """(depth, width) after the paper's three-level rounding rules."""
    blocks = _ceil_pow2(n_src)
    rows_per_block = _ceil_pow2(math.ceil(n_dst / 8))
    width = 8 * w_bits
    return blocks * rows_per_block, width


def _neuron_state_dims(cfg: LayerConfig) -> tuple[int, int]:
    state_bits = cfg.u_bits + (cfg.i_bits if cfg.neuron == NeuronModel.SYNAPTIC else 0)
    width = 8 * math.ceil(state_bits / 8)  # byte-boundary rounding
    depth = _ceil_pow2(cfg.n_out)
    return depth, width


# --------------------------------------------------------------------------
# LUT / FF datapath model (regression form, anchored to the paper's design)
# --------------------------------------------------------------------------

# Per-core linear coefficients. Interpretations: weight-datapath slices per
# weight bit, membrane ALU slices per state bit, CG adder slices per shift
# tap, plus a fixed controller+SPI+AMU base solved from the anchor below.
_LUT_PER_W_BIT = 18.0
_LUT_PER_U_BIT = 22.0
_LUT_PER_I_BIT = 14.0
_LUT_PER_RECW_BIT = 12.0
_LUT_PER_CG_TAP = 8.0

_FF_PER_W_BIT = 8.0
_FF_PER_U_BIT = 14.0
_FF_PER_I_BIT = 9.0
_FF_PER_RECW_BIT = 6.0
_FF_PER_CG_TAP = 4.0

# Anchor: 2 identical-shape FF/LIF cores (w=6, u=8, 8 CG taps) total
# 934 LUT / 689 FF *including* LUTRAM-mapped neuron-state memories.
_ANCHOR_LUT_TOTAL = 934.0
_ANCHOR_FF_TOTAL = 689.0


def _anchor_cores() -> list[LayerConfig]:
    return [
        LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=8),
        LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=8),
    ]


def _variable_lut(cfg: LayerConfig) -> float:
    lut = _LUT_PER_W_BIT * cfg.w_bits + _LUT_PER_U_BIT * cfg.u_bits
    if cfg.neuron == NeuronModel.SYNAPTIC:
        lut += _LUT_PER_I_BIT * cfg.i_bits
    if cfg.topology == Topology.ATA_T:
        lut += _LUT_PER_RECW_BIT * cfg.w_rec_bits
    lut += _LUT_PER_CG_TAP * cfg.leak_bits
    return lut


def _variable_ff(cfg: LayerConfig) -> float:
    ff = _FF_PER_W_BIT * cfg.w_bits + _FF_PER_U_BIT * cfg.u_bits
    if cfg.neuron == NeuronModel.SYNAPTIC:
        ff += _FF_PER_I_BIT * cfg.i_bits
    if cfg.topology == Topology.ATA_T:
        ff += _FF_PER_RECW_BIT * cfg.w_rec_bits
    ff += _FF_PER_CG_TAP * cfg.leak_bits
    return ff


def _lutram_luts(cfg: LayerConfig) -> float:
    """LUTs consumed by memories small enough to map to distributed RAM."""
    total = 0.0
    for depth, width in _memory_list(cfg):
        bits = depth * width
        if bits <= _LUTRAM_THRESHOLD_BITS:
            total += bits / _LUTRAM_BITS_PER_LUT
    return total


def _memory_list(cfg: LayerConfig) -> list[tuple[int, int]]:
    mems = [_synaptic_memory_dims(cfg.n_in, cfg.n_out, cfg.w_bits)]
    if cfg.topology == Topology.ATA_T:
        mems.append(_synaptic_memory_dims(cfg.n_out, cfg.n_out, cfg.w_rec_bits))
    mems.append(_neuron_state_dims(cfg))
    return mems


def _solve_bases() -> tuple[float, float]:
    cores = _anchor_cores()
    var_lut = sum(_variable_lut(c) + _lutram_luts(c) for c in cores)
    var_ff = sum(_variable_ff(c) for c in cores)
    base_lut = (_ANCHOR_LUT_TOTAL - var_lut) / len(cores)
    base_ff = (_ANCHOR_FF_TOTAL - var_ff) / len(cores)
    return base_lut, base_ff


_BASE_LUT, _BASE_FF = _solve_bases()


@dataclasses.dataclass(frozen=True)
class CoreResources:
    lut: float
    ff: float
    bram: int

    @property
    def logic_cells(self) -> float:
        return self.lut + self.ff

    def __add__(self, other: "CoreResources") -> "CoreResources":
        return CoreResources(self.lut + other.lut, self.ff + other.ff, self.bram + other.bram)


def core_resources(cfg: LayerConfig) -> CoreResources:
    lut = _BASE_LUT + _variable_lut(cfg) + _lutram_luts(cfg)
    ff = _BASE_FF + _variable_ff(cfg)
    bram = 0
    for depth, width in _memory_list(cfg):
        if depth * width > _LUTRAM_THRESHOLD_BITS:
            bram += bram36_count(depth, width)
    return CoreResources(lut=lut, ff=ff, bram=bram)


def network_resources(net: NetworkConfig) -> CoreResources:
    total = CoreResources(0.0, 0.0, 0)
    for cfg in net.layers:
        total = total + core_resources(cfg)
    return total


# --------------------------------------------------------------------------
# Latency model (60 MHz, pipelined cores, per-neuron sequential sweeps)
# --------------------------------------------------------------------------

CLOCK_HZ = 60e6
_CONTROLLER_OVERHEAD_CYCLES = 100  # per step per core (paper's controller loop)


def step_cycles(cfg: LayerConfig, n_in_events: float, n_rec_events: float) -> float:
    """Cycles one core spends on one time step.

    FF-Integ sweeps all n_out neurons per incoming ASPL; REC-Integ sweeps
    n_out per ASCL under ATA-T but only the source neuron under ATA-F; the
    Leak/Spike phase visits every neuron once.
    """
    cycles = n_in_events * cfg.n_out
    if cfg.topology == Topology.ATA_T:
        cycles += n_rec_events * cfg.n_out
    elif cfg.topology == Topology.ATA_F:
        cycles += n_rec_events
    cycles += cfg.n_out  # leak / spike-generation sweep
    return cycles + _CONTROLLER_OVERHEAD_CYCLES


def latency_seconds(
    net: NetworkConfig,
    input_events_per_step: np.ndarray,  # [T] mean ASPL count into layer 0
    layer_events_per_step: list[np.ndarray],  # per layer, [T] mean emitted spikes
) -> float:
    """End-to-end latency of one sample through the pipelined multi-core system.

    Cores overlap across time steps (layer L works on step t while L+1 works
    on step t-1), so the steady-state cost of a step is the *maximum* over
    cores, plus a pipeline fill of one step per extra core.
    """
    T = len(input_events_per_step)
    per_core_step_cycles = np.zeros((len(net.layers), T))
    for li, cfg in enumerate(net.layers):
        in_ev = input_events_per_step if li == 0 else layer_events_per_step[li - 1]
        rec_ev = layer_events_per_step[li] if cfg.is_recurrent else np.zeros(T)
        for t in range(T):
            # Recurrent events consumed at step t are the spikes of step t-1.
            rec_t = rec_ev[t - 1] if t > 0 else 0.0
            per_core_step_cycles[li, t] = step_cycles(cfg, float(in_ev[t]), float(rec_t))
    steady = per_core_step_cycles.max(axis=0).sum()
    fill = sum(
        per_core_step_cycles[li, 0] for li in range(len(net.layers) - 1)
    )  # drain of the first step through earlier cores
    return float(steady + fill) / CLOCK_HZ


# --------------------------------------------------------------------------
# Power / energy model
# --------------------------------------------------------------------------

# Zynq-7020-class static power, plus dynamic terms per resource and per
# event-rate; calibrated so the paper's MNIST point reports 111 mW total
# ("dominated by static power") and 0.12 mJ / image at 1.1 ms.
STATIC_WATTS = 0.095
_DYN_W_PER_LUT = 4.0e-6
_DYN_W_PER_BRAM = 1.0e-3
_DYN_W_PER_MEVENT_S = 2.0e-3  # switching power per million synaptic events/s


def power_watts(net: NetworkConfig, events_per_second: float = 0.0) -> float:
    res = network_resources(net)
    dyn = (
        _DYN_W_PER_LUT * res.logic_cells
        + _DYN_W_PER_BRAM * res.bram
        + _DYN_W_PER_MEVENT_S * events_per_second / 1e6
    )
    return STATIC_WATTS + dyn


def energy_per_image(net: NetworkConfig, latency_s: float, events_per_image: float) -> float:
    eps = events_per_image / latency_s if latency_s > 0 else 0.0
    return power_watts(net, eps) * latency_s
