"""AER event packets and the strict event-driven reference simulator.

Packet formats (paper section 4).  The paper does not pin the exact
control-payload encodings, so the concrete words below are *this repo's*
contract -- they are asserted verbatim by ``test_snn_core.py::
test_packet_words_pinned``, so this docstring and the codec cannot drift
apart without a test failure:

* ASPL -- Address of Spike in Previous Layer, 9 bits:
  ``{control=0, addr[7:0]}``; the word *is* the address
  (``encode_packet(ASPL, 0xAB) == 0x0AB``).
* ASCL -- Address of Spike in Current Layer, 8 bits: the bare address
  (``0xAB``).  The recurrent path has its own FIFO, so no control bit is
  needed; ``decode_packet(word, recurrent_path=True)`` disambiguates.
* EOTS -- End Of Time Step: control word ``0x100`` (control=1, payload 0).
* EOIN -- End Of INput:   control word ``0x101`` (control=1, payload 1).

EOIN lazy-reset semantics (asserted by ``test_snn_core.py::
test_eoin_lazy_reset_zeroes_state_after_spike_generation``): the EOIN step
is processed *normally* -- integration, leak, threshold compare and spike
emission all happen -- but during the leak/spike sweep the state writeback
is replaced by zeros (``U <- 0``, ``I_syn <- 0``).  Spikes of the final
step are therefore real outputs, while the next sample starts from virgin
state without spending a separate reset sweep.

:class:`EventDrivenCore` is a deliberately scalar, per-event Python/NumPy
model of one core: events are integrated one at a time with *per-event
saturation*, in arrival order, exactly as the RTL's FF-Integ/REC-Integ
microstates do.  It exists to pin the vectorised ``int_layer_step`` to the
hardware contract: property tests (``test_snn_core_props.py``) assert both
produce identical trajectories whenever no intermediate accumulation
saturates (and the strict model is the ground truth when one does).  Its
``cycle_count`` (one cycle per swept neuron visit) is the same accounting
rule the analytic latency model in ``repro.core.hw_model.step_cycles``
vectorises.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.fixed_point import int_max, int_min
from repro.core.snn_layer import LayerConfig, NeuronModel, ResetMode, Topology

__all__ = [
    "PacketKind",
    "encode_packet",
    "decode_packet",
    "raster_to_packets",
    "EventDrivenCore",
]

_CONTROL_BIT = 1 << 8


class PacketKind(str, enum.Enum):
    ASPL = "aspl"
    ASCL = "ascl"
    EOTS = "eots"
    EOIN = "eoin"


def encode_packet(kind: PacketKind, addr: int = 0) -> int:
    if kind == PacketKind.ASPL:
        if not 0 <= addr < 256:
            raise ValueError(f"ASPL address out of range: {addr}")
        return addr
    if kind == PacketKind.ASCL:
        if not 0 <= addr < 256:
            raise ValueError(f"ASCL address out of range: {addr}")
        return addr  # 8-bit packet on the recurrent path; context disambiguates
    if kind == PacketKind.EOTS:
        return _CONTROL_BIT | 0
    if kind == PacketKind.EOIN:
        return _CONTROL_BIT | 1
    raise ValueError(kind)


def decode_packet(word: int, recurrent_path: bool = False):
    if word & _CONTROL_BIT:
        payload = word & 0xFF
        return (PacketKind.EOIN if payload == 1 else PacketKind.EOTS), payload
    return (PacketKind.ASCL if recurrent_path else PacketKind.ASPL), word & 0xFF


def raster_to_packets(raster: np.ndarray) -> list[list[int]]:
    """Dense spike raster [T, n] -> per-step ASPL packet lists (+EOTS/EOIN).

    The driver acts as the input layer: it walks each time step, emits one
    ASPL per active source (ascending address = arrival order used by the
    reference core), then EOTS -- or EOIN after the final step.
    """
    raster = np.asarray(raster)
    T = raster.shape[0]
    steps = []
    for t in range(T):
        pkts = [encode_packet(PacketKind.ASPL, int(a)) for a in np.nonzero(raster[t])[0]]
        pkts.append(
            encode_packet(PacketKind.EOIN if t == T - 1 else PacketKind.EOTS)
        )
        steps.append(pkts)
    return steps


@dataclasses.dataclass
class EventDrivenCore:
    """Strict per-event, per-neuron scalar model of one core (ground truth)."""

    cfg: LayerConfig
    w_ff: np.ndarray  # int [n_in, n_out]
    w_rec: np.ndarray  # int [n_out, n_out] | scalar | empty
    theta_q: int

    def __post_init__(self):
        self.u = np.zeros(self.cfg.n_out, np.int64)
        self.i_syn = np.zeros(self.cfg.n_out, np.int64)
        self.prev_spk = np.zeros(self.cfg.n_out, np.int64)
        self._beta = self.cfg.beta_code()
        self._alpha = self.cfg.alpha_code()
        self.cycle_count = 0  # swept-neuron visits; feeds the latency model

    # -- helpers ---------------------------------------------------------
    def _sat(self, x: int, bits: int) -> int:
        return int(min(max(x, int_min(bits)), int_max(bits)))

    def _decay(self, x: int, code) -> int:
        if code.bypass:
            return int(x)
        acc = 0
        for shift in range(1, 9):
            if (code.k >> (8 - shift)) & 1:
                acc += int(np.asarray(x, np.int64)) >> shift
        return acc

    def _integrate_one(self, neuron: int, w: int):
        if self.cfg.neuron == NeuronModel.SYNAPTIC:
            self.i_syn[neuron] = self._sat(self.i_syn[neuron] + w, self.cfg.i_bits)
        else:
            self.u[neuron] = self._sat(self.u[neuron] + w, self.cfg.u_bits)
        self.cycle_count += 1

    # -- phases ----------------------------------------------------------
    def integrate_aspl(self, src: int):
        """FF-Integ: sweep all destination neurons for one input spike."""
        for n in range(self.cfg.n_out):
            self._integrate_one(n, int(self.w_ff[src, n]))

    def integrate_ascl(self, src: int):
        """REC-Integ: dense sweep (ATA-T) or self-only update (ATA-F)."""
        if self.cfg.topology == Topology.ATA_T:
            for n in range(self.cfg.n_out):
                self._integrate_one(n, int(self.w_rec[src, n]))
        elif self.cfg.topology == Topology.ATA_F:
            self._integrate_one(src, int(self.w_rec))

    def leak_spike_phase(self, lazy_reset: bool = False) -> list[int]:
        """Sequential neuron sweep; returns addresses of spiking neurons.

        With ``lazy_reset`` (the EOIN step) the sweep computes spikes
        normally but writes zeros back instead of the decayed/reset state --
        see the module docstring for the pinned semantics.
        """
        fired = []
        for n in range(self.cfg.n_out):
            if self.cfg.neuron == NeuronModel.SYNAPTIC:
                u_tmp = self._sat(self.u[n] + self.i_syn[n], self.cfg.u_bits)
            else:
                u_tmp = int(self.u[n])
            if u_tmp >= self.theta_q:
                fired.append(n)
                if self.cfg.reset == ResetMode.ZERO:
                    self.u[n] = 0
                else:
                    self.u[n] = self._sat(u_tmp - self.theta_q, self.cfg.u_bits)
            else:
                self.u[n] = self._sat(self._decay(u_tmp, self._beta), self.cfg.u_bits)
            if self.cfg.neuron == NeuronModel.SYNAPTIC:
                self.i_syn[n] = self._sat(
                    self._decay(self.i_syn[n], self._alpha), self.cfg.i_bits
                )
            self.cycle_count += 1
        if lazy_reset:
            # EOIN: zeros are written directly instead of the computed state.
            self.u[:] = 0
            self.i_syn[:] = 0
        return fired

    def step(self, aspl_sources: list[int], last: bool = False) -> list[int]:
        """Process one full time step worth of packets; returns fired addrs."""
        for src in aspl_sources:
            self.integrate_aspl(src)
        # EOTS/EOIN: recurrent events from the previous step, then leak/spike.
        if self.cfg.is_recurrent:
            for src in np.nonzero(self.prev_spk)[0]:
                self.integrate_ascl(int(src))
        fired = self.leak_spike_phase(lazy_reset=last)
        self.prev_spk[:] = 0
        if not last:
            self.prev_spk[fired] = 1
        return fired
