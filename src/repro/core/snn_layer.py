"""Per-core (= per-layer) SNN semantics: bit-exact integer and float variants.

One Flexi-NeurA core implements one layer.  The hardware processes a time
step in two phases (paper section 4.1.5):

  Phase A -- spike integration.  Each incoming ASPL event (a spike from the
  previous layer) adds the corresponding synaptic-weight column into the
  destination state: ``U`` for IF/LIF, ``I_syn`` for the Synaptic model.
  On EOTS, recurrent ASCL events (this layer's own spikes from the *previous*
  step) are integrated the same way (dense ``W_rec`` for ATA-T; a single
  shared self-weight register for ATA-F).

  Phase B -- leak / spike generation.  Neurons are swept sequentially by the
  time-multiplexed datapath; per neuron:
      Synaptic:  u_tmp = sat(U + I_syn)           (otherwise u_tmp = U)
      if u_tmp >= theta:  spike; U <- reset(u_tmp)   (reset-to-zero / by-subtract)
      else:               U <- CG_beta(u_tmp)        (no decay on the reset path)
      Synaptic:  I_syn <- CG_alpha(I_syn)            (decays every step)

The *vectorised* integer step below reproduces these numerics exactly
provided no intermediate event-by-event accumulation saturates (integration
is order-dependent only under saturation; ``repro.core.events`` provides the
strict per-event reference used by property tests to check this contract).

Timing convention: a spike generated in phase B of step ``t`` is the input
that the next layer integrates at its step ``t`` (cores run pipelined, one
step apart in wall-clock but aligned in step index), and is this layer's own
recurrent input at step ``t + 1`` -- matching SNN-Torch's unrolling, which
the paper trains against.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import coeff_gen
from repro.core.coeff_gen import DecayCode
from repro.core.fixed_point import saturate

__all__ = [
    "NeuronModel",
    "ResetMode",
    "Topology",
    "LayerConfig",
    "IntLayerParams",
    "LayerState",
    "int_layer_init",
    "int_layer_step",
    "int_layer_step_dynamic",
    "int_phase_a",
    "int_phase_b",
    "int_layer_window",
    "int_layer_window_carry",
    "int_layer_window_from_currents",
    "fused_eligible",
    "float_layer_init",
    "float_layer_step",
]


class NeuronModel(str, enum.Enum):
    IF = "if"  # realised as LIF with the CG bypass path (no leak)
    LIF = "lif"
    SYNAPTIC = "synaptic"


class ResetMode(str, enum.Enum):
    ZERO = "zero"
    SUBTRACT = "subtract"


class Topology(str, enum.Enum):
    FF = "ff"  # feed-forward only
    ATA_F = "ata_f"  # self-feedback only (one shared weight register)
    ATA_T = "ata_t"  # dense intra-layer recurrence


@dataclasses.dataclass(frozen=True)
class LayerConfig:
    """Design-time parameters of one Flexi-NeurA core (pre-synthesis)."""

    n_in: int
    n_out: int
    neuron: NeuronModel = NeuronModel.LIF
    topology: Topology = Topology.FF
    reset: ResetMode = ResetMode.SUBTRACT
    # Fixed-point widths (the Flex-plorer DSE knobs).
    w_bits: int = 6
    w_rec_bits: int = 6
    u_bits: int = 16
    i_bits: int = 16
    leak_bits: int = 8
    # Float dynamics (trained / user-chosen); quantized on deployment.
    beta: float = 0.95  # membrane leak
    alpha: float = 0.90  # synaptic-current leak (Synaptic model only)
    threshold: float = 1.0

    def __post_init__(self):
        if self.n_in <= 0 or self.n_out <= 0:
            raise ValueError("layer sizes must be positive")
        if self.n_out > 256 or self.n_in > 256:
            raise ValueError(
                "a Flexi-NeurA core supports at most 256 neurons per layer "
                f"(got n_in={self.n_in}, n_out={self.n_out}); split the layer "
                "across cores or reduce it as the paper does for its datasets"
            )
        for name in ("w_bits", "w_rec_bits"):
            b = getattr(self, name)
            if not 2 <= b <= 16:
                raise ValueError(f"{name} must be in [2, 16], got {b}")
        for name in ("u_bits", "i_bits"):
            b = getattr(self, name)
            if not 4 <= b <= 24:
                raise ValueError(f"{name} must be in [4, 24], got {b}")

    @property
    def is_recurrent(self) -> bool:
        return self.topology in (Topology.ATA_F, Topology.ATA_T)

    @property
    def effective_beta(self) -> float:
        # The IF model is the LIF datapath with the CG bypass engaged.
        return 1.0 if self.neuron == NeuronModel.IF else self.beta

    def beta_code(self) -> DecayCode:
        return coeff_gen.encode_decay(self.effective_beta, self.leak_bits)

    def alpha_code(self) -> DecayCode:
        return coeff_gen.encode_decay(self.alpha, self.leak_bits)


class IntLayerParams(NamedTuple):
    """Quantized runtime parameters (the SPI-loaded memories/registers)."""

    w_ff: jax.Array  # int32 [n_in, n_out]
    w_rec: jax.Array  # int32 [n_out, n_out] (ATA-T) | [] scalar (ATA-F) | [0] (FF)
    theta_q: jax.Array  # int32 scalar
    # Decay codes are static python (design/config-time), carried on LayerConfig.


class FloatLayerParams(NamedTuple):
    w_ff: jax.Array  # f32 [n_in, n_out]
    w_rec: jax.Array  # f32 [n_out, n_out] | scalar | [0]
    theta: jax.Array  # f32 scalar


class LayerState(NamedTuple):
    u: jax.Array  # membrane potential  [batch, n_out]
    i_syn: jax.Array  # synaptic current [batch, n_out] (zeros-shaped if unused)
    prev_spk: jax.Array  # this layer's spikes from the previous step [batch, n_out]


def _rec_weight_shape(cfg: LayerConfig):
    if cfg.topology == Topology.ATA_T:
        return (cfg.n_out, cfg.n_out)
    if cfg.topology == Topology.ATA_F:
        return ()  # single shared self-weight register (SPI ALL_TO_ALL_FALSE_WEIGHT)
    return (0,)


def int_layer_init(cfg: LayerConfig, batch: int) -> LayerState:
    # Three distinct buffers, not one shared zeros array: serving donates
    # the lane-carry state, and XLA rejects donating an aliased buffer twice.
    z = lambda: jnp.zeros((batch, cfg.n_out), jnp.int32)
    return LayerState(u=z(), i_syn=z(), prev_spk=z())


def float_layer_init(cfg: LayerConfig, batch: int) -> LayerState:
    z = lambda: jnp.zeros((batch, cfg.n_out), jnp.float32)
    return LayerState(u=z(), i_syn=z(), prev_spk=z())


def _integrate_acc(cfg: LayerConfig, params: IntLayerParams, state: LayerState, ff_acc):
    """Phase A given the step's feed-forward accumulation ``ff_acc``.

    Adds the recurrent contribution (the previous step's own spikes) and
    commits the total into the integration target register.  Saturation is
    applied once, after the full step's accumulation -- int32 addition is
    associative, so any exact method of computing ``ff_acc`` (dense matmul,
    Pallas kernel, sparse gather over active rows) yields identical state.
    """
    acc = ff_acc
    if cfg.topology == Topology.ATA_T:
        acc = acc + jnp.einsum("bi,io->bo", state.prev_spk, params.w_rec)
    elif cfg.topology == Topology.ATA_F:
        acc = acc + state.prev_spk * params.w_rec
    if cfg.neuron == NeuronModel.SYNAPTIC:
        return state.u, saturate(state.i_syn + acc, cfg.i_bits)
    return saturate(state.u + acc, cfg.u_bits), state.i_syn


def int_phase_a(cfg: LayerConfig, params: IntLayerParams, state: LayerState, s_in):
    """Phase A: accumulate weighted spikes into the integration target.

    Public because the QAT straight-through forward (``repro.snn.qat``) runs
    its exact forward values through this code path -- bit-for-bit the
    deployment arithmetic, per phase so the float mirror can attach at every
    intermediate.
    """
    s_in_i = s_in.astype(jnp.int32)
    ff_acc = jnp.einsum("bi,io->bo", s_in_i, params.w_ff)  # {0,1} matmul, int32
    return _integrate_acc(cfg, params, state, ff_acc)


def int_phase_b(cfg: LayerConfig, params: IntLayerParams, u, i_syn, decay_u, decay_i):
    """Phase B (leak / spike / reset), shared by the static and traced steps.

    ``decay_u`` / ``decay_i`` are the CG applications -- the *only* place the
    static-register and traced-register datapaths differ, so this is the
    single copy of the spike/reset/leak numerics.
    """
    if cfg.neuron == NeuronModel.SYNAPTIC:
        u_tmp = saturate(u + i_syn, cfg.u_bits)
    else:
        u_tmp = u

    spk = (u_tmp >= params.theta_q).astype(jnp.int32)
    if cfg.reset == ResetMode.ZERO:
        u_reset = jnp.zeros_like(u_tmp)
    else:
        u_reset = saturate(u_tmp - params.theta_q, cfg.u_bits)
    u_leak = saturate(decay_u(u_tmp), cfg.u_bits)
    u_new = jnp.where(spk == 1, u_reset, u_leak)

    if cfg.neuron == NeuronModel.SYNAPTIC:
        i_new = saturate(decay_i(i_syn), cfg.i_bits)
    else:
        i_new = i_syn

    return LayerState(u=u_new, i_syn=i_new, prev_spk=spk), spk


def int_layer_step(
    cfg: LayerConfig, params: IntLayerParams, state: LayerState, s_in
) -> tuple[LayerState, jax.Array]:
    """One bit-exact hardware time step. Returns (new_state, spikes int32)."""
    beta_code = cfg.beta_code()
    u, i_syn = int_phase_a(cfg, params, state, s_in)
    return int_phase_b(
        cfg,
        params,
        u,
        i_syn,
        lambda x: coeff_gen.apply_decay(x, beta_code),
        lambda x: coeff_gen.apply_decay(x, cfg.alpha_code()),
    )


def int_layer_step_dynamic(
    cfg: LayerConfig,
    params: IntLayerParams,
    state: LayerState,
    s_in,
    beta_register,
    alpha_register,
) -> tuple[LayerState, jax.Array]:
    """Bit-exact step with *traced* DecayRate registers (population DSE path).

    Identical numerics to :func:`int_layer_step`, but the CG registers are jax
    values, so a vmap over candidates (whose ``leak_bits`` differ) compiles to
    one program.  ``beta_register`` / ``alpha_register`` are packed 9-bit
    ``DecayCode.decay_rate_register`` values.
    """
    u, i_syn = int_phase_a(cfg, params, state, s_in)
    return int_phase_b(
        cfg,
        params,
        u,
        i_syn,
        lambda x: coeff_gen.apply_decay_traced(x, beta_register),
        lambda x: coeff_gen.apply_decay_traced(x, alpha_register),
    )


def fused_eligible(cfg: LayerConfig) -> bool:
    """True when a layer's window can run through the fused kernel path.

    The fused path (int spike-weight matmul feeding the ``lif_scan`` Pallas
    kernel) covers the IF/LIF datapath with either reset mode on purely
    feed-forward cores.  Recurrent topologies (the next step's input depends
    on this step's spikes) and the Synaptic model (a second state register)
    stay on the step-major reference semantics.
    """
    return cfg.topology == Topology.FF and cfg.neuron in (
        NeuronModel.IF,
        NeuronModel.LIF,
    )


def int_layer_window(cfg: LayerConfig, params: IntLayerParams, raster) -> jax.Array:
    """Run one layer over a whole window. ``raster``: int [T, batch, n_in].

    Returns the output spike raster int32 [T, batch, n_out].  This is the
    layer-major traversal used by backends that process the network
    core-by-core instead of step-by-step; numerics are exactly
    ``int_layer_step`` iterated over the window.
    """
    state0 = int_layer_init(cfg, raster.shape[1])

    def step(state, s_t):
        state, spk = int_layer_step(cfg, params, state, s_t)
        return state, spk

    _, spikes = jax.lax.scan(step, state0, raster.astype(jnp.int32))
    return spikes


def int_layer_window_carry(
    cfg: LayerConfig, params: IntLayerParams, state: LayerState, ff_currents, live=None
) -> tuple[LayerState, jax.Array]:
    """Carried-state form of :func:`int_layer_window_from_currents`.

    Starts from ``state`` (instead of a fresh init) and returns the state
    after the window alongside the spikes -- the seam for callers that
    advance a layer chunk-by-chunk (the serving engine's lane pool): running
    two consecutive chunks through this function is bit-identical to one
    longer window, which is bit-identical to iterated
    :func:`int_layer_step`.

    ``live`` (optional bool [T, batch]) freezes a batch element's carry once
    its liveness goes False: the step still computes, but the committed state
    is the pre-step state, so the returned carry is *exactly* the state after
    that element's last live step.  This is the chunk-quantisation seam for
    persistent streams: a caller may pad a lane's chunk past its real data
    and still read back a bit-exact carry at the data boundary (padding
    steps would otherwise decay the membrane / advance ``prev_spk``).
    Spikes emitted on dead steps are garbage-but-harmless: downstream
    layers' states are frozen on the same mask, and window callers mask
    recorded outputs.
    """
    beta_code = cfg.beta_code()
    alpha_code = cfg.alpha_code()

    def step(state, inp):
        c_t = inp if live is None else inp[0]
        u, i_syn = _integrate_acc(cfg, params, state, c_t)
        new_state, spk = int_phase_b(
            cfg,
            params,
            u,
            i_syn,
            lambda x: coeff_gen.apply_decay(x, beta_code),
            lambda x: coeff_gen.apply_decay(x, alpha_code),
        )
        if live is not None:
            live_t = inp[1][:, None]  # [batch, 1]
            new_state = jax.tree.map(
                lambda n, o: jnp.where(live_t, n, o), new_state, state
            )
        return new_state, spk

    xs = ff_currents.astype(jnp.int32)
    if live is not None:
        xs = (xs, live)
    return jax.lax.scan(step, state, xs)


def int_layer_window_from_currents(
    cfg: LayerConfig, params: IntLayerParams, ff_currents
) -> jax.Array:
    """Run one layer over a window of *precomputed* FF integration currents.

    ``ff_currents``: int32 [T, batch, n_out], the per-step feed-forward
    accumulation ``s_t @ w_ff`` (however it was computed -- this is the seam
    the event-driven backend uses to feed sparse-gathered currents into the
    exact step dynamics).  The scan adds recurrent contributions and runs
    phase B per step, so *every* neuron model / topology / reset mode is
    covered with numerics identical to :func:`int_layer_step`.
    """
    state0 = int_layer_init(cfg, ff_currents.shape[1])
    _, spikes = int_layer_window_carry(cfg, params, state0, ff_currents)
    return spikes


def _integrate_float(cfg: LayerConfig, params: FloatLayerParams, state: LayerState, s_in):
    acc = jnp.einsum("bi,io->bo", s_in.astype(jnp.float32), params.w_ff)
    if cfg.topology == Topology.ATA_T:
        acc = acc + jnp.einsum("bi,io->bo", state.prev_spk, params.w_rec)
    elif cfg.topology == Topology.ATA_F:
        acc = acc + state.prev_spk * params.w_rec
    if cfg.neuron == NeuronModel.SYNAPTIC:
        return state.u, state.i_syn + acc
    return state.u + acc, state.i_syn


def float_layer_step(
    cfg: LayerConfig,
    params: FloatLayerParams,
    state: LayerState,
    s_in,
    spike_fn,
) -> tuple[LayerState, jax.Array]:
    """Differentiable step with the *same phase ordering* as the hardware.

    ``spike_fn(u - theta)`` must return {0,1} forward with a surrogate
    gradient (see repro.snn.surrogate).  Keeping the hardware's
    decay-or-reset ordering at train time removes the train/deploy semantic
    gap that a vanilla SNN-Torch unrolling would leave.
    """
    beta = cfg.effective_beta
    u, i_syn = _integrate_float(cfg, params, state, s_in)
    u_tmp = u + i_syn if cfg.neuron == NeuronModel.SYNAPTIC else u

    spk = spike_fn(u_tmp - params.theta)
    if cfg.reset == ResetMode.ZERO:
        u_reset = jnp.zeros_like(u_tmp)
    else:
        u_reset = u_tmp - params.theta
    # jax.lax.stop_gradient on the branch selector is implicit: spk already
    # carries the surrogate gradient; mixing via arithmetic keeps it flowing.
    u_new = spk * u_reset + (1.0 - spk) * (beta * u_tmp)

    if cfg.neuron == NeuronModel.SYNAPTIC:
        i_new = cfg.alpha * i_syn
    else:
        i_new = i_syn
    return LayerState(u=u_new, i_syn=i_new, prev_spk=spk), spk
