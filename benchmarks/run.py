"""Benchmark harness: one module per paper table/figure + framework extras.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  table1    -- paper Table 1 (neuron x topology x dataset accuracy sweep)
  table2    -- paper Table 2 (MNIST design point: resources/latency/energy)
  fig11     -- paper Fig. 11 (precision-DSE cost landscape, ATA-F on DVS)
  cg_error  -- section 4.1.2 CG approximation-error claims
  lm_dse    -- Flex-plorer generalised to LM serving precision (beyond paper)
  kernels   -- kernel micro-benchmarks (oracle timing + modeled TPU time)
  backend   -- inference-backend throughput + DSE candidate rate
               (reference vs fused, serial vs population; BENCH_backend.json)
  event     -- event-driven backend throughput vs input sparsity
               (reference vs fused vs event; BENCH_event.json)
  serve     -- continuous-batching SNN service vs serial run_int
               (closed-loop + offered-load p50/p99; BENCH_serve.json)
  shard     -- multi-device scaling: eval/DSE/serving at 1/2/4 forced host
               devices (worker subprocesses; BENCH_shard.json)
  qat       -- post-training quant vs quantization-aware training accuracy
               at w_bits 2/3/4 + refined-front DSE (BENCH_qat.json)
  dse       -- search-strategy quality: anneal vs NSGA-II front hypervolume
               at equal budget, resume fidelity, population-sweep
               candidates/sec at 1/4 forced host devices (BENCH_dse.json)
  roofline  -- per (arch x shape) roofline terms from the dry-run records

Usage: python -m benchmarks.run [--only table1,roofline] [--fast]
       python -m benchmarks.run --compile-cache DIR [...]   # persistent jit cache
       python -m benchmarks.run --check-regression          # gate BENCH_*.json
                                                            # against baselines

``--check-regression`` compares the repo-root ``BENCH_*.json`` files (the
committed perf trajectory, refreshed by a full ``benchmarks.run`` pass)
against ``benchmarks/baselines/`` and exits nonzero when any throughput
metric (``*_per_sec`` keys; offered-load *inputs* excluded) regresses by
more than the threshold (default 25%).  Record a new baseline by copying
the fresh ``BENCH_*.json`` into ``benchmarks/baselines/``.
"""

import argparse
import json
import pathlib
import re
import sys
import traceback

MODULES = ["cg_error", "kernels", "backend", "event", "serve", "shard", "qat", "dse", "roofline", "lm_dse", "table2", "table1", "fig11"]

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = _ROOT / "benchmarks" / "baselines"

# Throughput metrics: higher is better.  `offered_rate_per_sec` is a load
# *parameter* (what the generator asked for), not a measurement -- skip it.
_THROUGHPUT_KEY = re.compile(r"per_sec$")
_EXCLUDE_KEY = re.compile(r"^offered_rate")


def _rows(name: str, fast: bool):
    if name == "table1":
        from benchmarks import table1_accuracy

        return table1_accuracy.run(epochs=2 if fast else 8)
    if name == "table2":
        from benchmarks import table2_resources

        return table2_resources.run(epochs=3 if fast else 8)
    if name == "fig11":
        from benchmarks import fig11_dse

        return fig11_dse.run(epochs=2 if fast else 5)
    if name == "cg_error":
        from benchmarks import cg_error

        return cg_error.run()
    if name == "lm_dse":
        from benchmarks import lm_dse

        return lm_dse.run(archs=("mamba2-780m",) if fast else ("gemma2-27b", "qwen2-moe-a2.7b", "mamba2-780m"))
    if name == "kernels":
        from benchmarks import kernels_micro

        return kernels_micro.run()
    if name == "backend":
        from benchmarks import backend_bench

        return backend_bench.run(fast=fast)
    if name == "event":
        from benchmarks import event_bench

        return event_bench.run(fast=fast)
    if name == "serve":
        from benchmarks import serve_bench

        return serve_bench.run(fast=fast)
    if name == "shard":
        from benchmarks import shard_bench

        return shard_bench.run(fast=fast)
    if name == "qat":
        from benchmarks import qat_bench

        return qat_bench.run(fast=fast)
    if name == "dse":
        from benchmarks import dse_bench

        return dse_bench.run(fast=fast)
    if name == "roofline":
        from benchmarks import roofline

        return roofline.run()
    raise KeyError(name)


def _throughput_leaves(obj, prefix: str = "") -> dict[str, float]:
    """Flatten a bench report to {dotted.path: value} for throughput keys."""
    leaves: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                leaves.update(_throughput_leaves(v, path))
            elif (
                isinstance(v, (int, float))
                and _THROUGHPUT_KEY.search(str(k))
                and not _EXCLUDE_KEY.search(str(k))
            ):
                leaves[path] = float(v)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            leaves.update(_throughput_leaves(v, f"{prefix}[{i}]"))
    return leaves


def check_regression(
    fresh_dir: pathlib.Path = _ROOT,
    baseline_dir: pathlib.Path = BASELINE_DIR,
    threshold: float = 0.25,
) -> list[str]:
    """Compare fresh BENCH_*.json against baselines; return regression lines.

    A metric regresses when ``fresh < (1 - threshold) * baseline``.  Metrics
    missing from the fresh report (renamed/removed) are reported too --
    silently dropping a measurement must not read as "no regression".
    Baselines that do not exist yet are skipped (that is how the trajectory
    starts; record one by copying the fresh file into the baseline dir).
    """
    problems: list[str] = []
    for base_file in sorted(baseline_dir.glob("BENCH_*.json")):
        fresh_file = fresh_dir / base_file.name
        if not fresh_file.exists():
            problems.append(f"{base_file.name}: fresh report missing (run the bench first)")
            continue
        base = _throughput_leaves(json.loads(base_file.read_text()))
        fresh = _throughput_leaves(json.loads(fresh_file.read_text()))
        for path, base_val in sorted(base.items()):
            got = fresh.get(path)
            if got is None:
                problems.append(f"{base_file.name}: {path} missing from fresh report")
            elif got < (1.0 - threshold) * base_val:
                problems.append(
                    f"{base_file.name}: {path} regressed {base_val:.1f} -> {got:.1f} "
                    f"({got / base_val:.2f}x, floor {1.0 - threshold:.2f}x)"
                )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable jax's persistent compilation cache at DIR "
                    "(repeat runs skip recompiles)")
    ap.add_argument("--check-regression", action="store_true",
                    help="compare repo-root BENCH_*.json against "
                    "benchmarks/baselines/ and exit nonzero on regression")
    ap.add_argument("--baseline-dir", default=None,
                    help="baseline directory for --check-regression")
    ap.add_argument("--regression-threshold", type=float, default=0.25,
                    help="allowed fractional throughput drop (default 0.25)")
    args = ap.parse_args()

    if args.check_regression:
        baseline_dir = pathlib.Path(args.baseline_dir) if args.baseline_dir else BASELINE_DIR
        problems = check_regression(threshold=args.regression_threshold, baseline_dir=baseline_dir)
        if problems:
            print(f"{len(problems)} throughput regression(s) vs {baseline_dir}:")
            for p in problems:
                print(f"  {p}")
            raise SystemExit(1)
        print(f"no throughput regressions vs {baseline_dir}")
        return

    if args.compile_cache:
        from repro.distributed.compat import enable_compilation_cache

        if not enable_compilation_cache(args.compile_cache):
            print("# persistent compilation cache unavailable on this jax", file=sys.stderr)

    names = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failed = False
    for name in names:
        try:
            for row_name, us, derived in _rows(name, args.fast):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:
            failed = True
            print(f"{name},0.0,EXCEPTION:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
