"""Benchmark harness: one module per paper table/figure + framework extras.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  table1    -- paper Table 1 (neuron x topology x dataset accuracy sweep)
  table2    -- paper Table 2 (MNIST design point: resources/latency/energy)
  fig11     -- paper Fig. 11 (precision-DSE cost landscape, ATA-F on DVS)
  cg_error  -- section 4.1.2 CG approximation-error claims
  lm_dse    -- Flex-plorer generalised to LM serving precision (beyond paper)
  kernels   -- kernel micro-benchmarks (oracle timing + modeled TPU time)
  backend   -- inference-backend throughput + DSE candidate rate
               (reference vs fused, serial vs population; BENCH_backend.json)
  event     -- event-driven backend throughput vs input sparsity
               (reference vs fused vs event; BENCH_event.json)
  serve     -- continuous-batching SNN service vs serial run_int
               (closed-loop + offered-load p50/p99; BENCH_serve.json)
  roofline  -- per (arch x shape) roofline terms from the dry-run records

Usage: python -m benchmarks.run [--only table1,roofline] [--fast]
"""

import argparse
import sys
import traceback

MODULES = ["cg_error", "kernels", "backend", "event", "serve", "roofline", "lm_dse", "table2", "table1", "fig11"]


def _rows(name: str, fast: bool):
    if name == "table1":
        from benchmarks import table1_accuracy

        return table1_accuracy.run(epochs=2 if fast else 8)
    if name == "table2":
        from benchmarks import table2_resources

        return table2_resources.run(epochs=3 if fast else 8)
    if name == "fig11":
        from benchmarks import fig11_dse

        return fig11_dse.run(epochs=2 if fast else 5)
    if name == "cg_error":
        from benchmarks import cg_error

        return cg_error.run()
    if name == "lm_dse":
        from benchmarks import lm_dse

        return lm_dse.run(archs=("mamba2-780m",) if fast else ("gemma2-27b", "qwen2-moe-a2.7b", "mamba2-780m"))
    if name == "kernels":
        from benchmarks import kernels_micro

        return kernels_micro.run()
    if name == "backend":
        from benchmarks import backend_bench

        return backend_bench.run(fast=fast)
    if name == "event":
        from benchmarks import event_bench

        return event_bench.run(fast=fast)
    if name == "serve":
        from benchmarks import serve_bench

        return serve_bench.run(fast=fast)
    if name == "roofline":
        from benchmarks import roofline

        return roofline.run()
    raise KeyError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failed = False
    for name in names:
        try:
            for row_name, us, derived in _rows(name, args.fast):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:
            failed = True
            print(f"{name},0.0,EXCEPTION:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
