"""Kernel microbenchmarks (CPU: oracle paths; the Pallas kernels are TPU-
target and validated in interpret mode by tests/test_kernels.py).

Times the jnp oracle implementations and reports the *modeled* TPU kernel
timings from the roofline (bytes/flops at v5e constants), so the CSV carries
both a measured number and the number that matters for the deployment
target.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.precision import quantize_weight
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.kernels.lif_scan.ref import lif_scan_ref


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / n * 1e6


def run() -> list[tuple[str, float, str]]:
    out = []
    # lif_scan oracle: T=100 window, 1024 neurons, batch 64
    cur = jax.random.randint(jax.random.PRNGKey(0), (100, 64, 1024), -200, 300, jnp.int32)
    f = jax.jit(lambda c: lif_scan_ref(c, 500, 153, 16, False))
    us = _time(f, cur)
    # modeled TPU time: one HBM pass over currents + spikes at 819 GB/s
    model_us = (cur.size * 4 * 2 / 819e9) * 1e6
    out.append(("kernels/lif_scan_oracle_T100_64x1024", us, f"modeled_tpu_us={model_us:.1f}"))

    # quant matmul oracle (XLA-fused dequant): decode-shaped 8 x 4096 x 14336
    w = jax.random.normal(jax.random.PRNGKey(1), (4096, 14336), jnp.float32) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 4096), jnp.float32).astype(jnp.bfloat16)
    for bits in (8, 4):
        qt = quantize_weight(w, bits)
        f = jax.jit(lambda x, q=qt: quant_matmul_ref(x, q))
        us = _time(f, x)
        bytes_w = qt.q.size * 1  # int8 storage (packed for 4-bit)
        model_us = (bytes_w / 819e9) * 1e6  # memory-bound decode matmul
        out.append(
            (f"kernels/quant_matmul_oracle_b{bits}_8x4096x14336", us, f"modeled_tpu_us={model_us:.1f};weight_mb={bytes_w/1e6:.1f}")
        )
    return out
