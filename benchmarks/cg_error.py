"""Section 4.1.2 claim: CG approximates any decay in [0,1] with 1/256
granularity and worst-case factor rounding error below 1/512.

Sweeps the full factor range and both error senses (factor error from grid
rounding; value error from floor shifts) at each tap budget.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import coeff_gen


def run() -> list[tuple[str, float, str]]:
    t0 = time.time()
    betas = np.linspace(0.0, 1.0, 2001)
    out = []
    for leak_bits in (3, 8):
        max_factor_err = 0.0
        max_value_err = 0.0
        x = jnp.arange(-4096, 4097, 37, dtype=jnp.int32)
        for b in betas:
            code = coeff_gen.encode_decay(float(b), leak_bits)
            max_factor_err = max(max_factor_err, abs(code.factor - float(b)))
            got = np.asarray(coeff_gen.apply_decay(x, code), np.float64)
            exact = np.asarray(x, np.float64) * code.factor
            max_value_err = max(max_value_err, float(np.max(np.abs(got - exact))))
        grid_half = (1 << (8 - leak_bits)) / 512.0
        out.append(
            (
                f"cg_error/leak_bits={leak_bits}",
                (time.time() - t0) * 1e6,
                f"max_factor_err={max_factor_err:.6f}(bound {grid_half:.6f})"
                f";max_value_err_lsb={max_value_err:.2f}(taps<=8);claim_1_512={'PASS' if leak_bits < 8 or max_factor_err <= 1/512 + 1e-12 else 'FAIL'}",
            )
        )
    return out
