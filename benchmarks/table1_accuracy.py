"""Paper Table 1: trained network configurations x classification accuracy.

Reproduces the paper's sweep (neuron model x topology x dataset) on the
synthetic stand-in benchmarks at smoke scale.  Paper accuracies are quoted
alongside for reference -- absolute numbers are not comparable (different
data; offline container), the *ordering and pipeline* are the reproduction.
"""

from __future__ import annotations

import time

from repro.core.network import NetworkConfig, quantize_params
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology
from repro.data.snn_datasets import dvs_like, mnist_like, shd_like
from repro.snn.train import eval_int, train_snn

# (neuron, topology, dataset, paper_steps, paper_accuracy) -- paper Table 1 rows
ROWS = [
    (NeuronModel.LIF, Topology.FF, "mnist", 100, 0.9805),  # row 1
    (NeuronModel.IF, Topology.FF, "mnist", 80, 0.9710),  # row 4
    (NeuronModel.SYNAPTIC, Topology.FF, "mnist", 60, 0.9765),  # row 6
    (NeuronModel.LIF, Topology.ATA_F, "mnist", 50, 0.9620),  # row 10
    (NeuronModel.LIF, Topology.ATA_T, "mnist", 50, 0.9651),  # row 13
    (NeuronModel.LIF, Topology.FF, "shd", 110, 0.7089),  # row 9
    (NeuronModel.SYNAPTIC, Topology.FF, "shd", 80, 0.6756),  # row 5
    (NeuronModel.LIF, Topology.FF, "dvs", 60, 0.8456),  # row 18
    (NeuronModel.IF, Topology.ATA_F, "dvs", 70, 0.8333),  # row 16
]

_DATA_CACHE = {}


def _dataset(name: str, T: int):
    key = (name, T)
    if key not in _DATA_CACHE:
        if name == "mnist":
            ds = mnist_like(n=1536, T=T, seed=0)
        elif name == "shd":
            ds = shd_like(n=1200, T=T, seed=1)
        else:
            ds = dvs_like(n=1200, T=T, seed=2)
        _DATA_CACHE[key] = ds.split()
    return _DATA_CACHE[key]


def _net(neuron, topo, n_in, n_classes, T):
    # the synaptic model double-integrates (I_syn then U): it needs a higher
    # threshold and faster current leak to stay in a useful firing regime
    thr = 2.5 if neuron == NeuronModel.SYNAPTIC else 1.0
    alpha = 0.7
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=n_in, n_out=128, neuron=neuron, topology=topo, w_bits=6, u_bits=16, threshold=thr, alpha=alpha),
            LayerConfig(n_in=128, n_out=n_classes, neuron=neuron, topology=Topology.FF, w_bits=6, u_bits=16, threshold=thr, alpha=alpha),
        ),
        n_steps=T,
        name=f"{neuron.value}-{topo.value}",
    )


def run(epochs: int = 8, T: int = 20) -> list[tuple[str, float, str]]:
    out = []
    for neuron, topo, data, paper_T, paper_acc in ROWS:
        train, test = _dataset(data, T)
        n_in = train.spikes.shape[-1]
        net = _net(neuron, topo, n_in, train.n_classes, T)
        t0 = time.time()
        res = train_snn(net, train, epochs=epochs, batch_size=128, lr=2e-3)
        qparams, _ = quantize_params(net, res.params)
        acc = eval_int(net, qparams, test)
        us = (time.time() - t0) * 1e6
        name = f"table1/{neuron.value}-{topo.value}-{data}"
        out.append((name, us, f"acc={acc:.4f};paper={paper_acc:.4f}"))
    return out
