"""Roofline report: renders EXPERIMENTS.md tables from the dry-run records.

One row per (arch x shape) on the single-pod mesh (the assignment's roofline
scope); multi-pod rows prove the pod axis lowers and are summarised
separately.  ``us_per_call`` in the bench CSV is the modeled roofline-bound
step time (the max of the three terms) in microseconds.
"""

from __future__ import annotations

import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_records(mesh: str = "single", variant: str | None = None):
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh:
            continue
        v = r.get("variant", "baseline")
        if variant is None and v != "baseline":
            continue
        if variant is not None and v != variant:
            continue
        recs.append(r)
    return recs


def markdown_table(recs) -> str:
    hdr = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "roofline frac | useful FLOPs ratio | fits HBM |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: {r.get('reason','')[:40]} | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['dominant'].replace('_s','')} | {t['roofline_fraction']:.2f} | "
            f"{ratio:.2f} | {'yes' if r.get('fits_hbm') else 'NO'} |"
        )
    return hdr + "\n".join(rows)


def worst_cells(recs, n=5):
    ok = [r for r in recs if r["status"] == "ok"]
    return sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:n]


def most_collective_bound(recs, n=5):
    ok = [r for r in recs if r["status"] == "ok"]
    return sorted(
        ok,
        key=lambda r: r["roofline"]["collective_s"] / (sum(
            r["roofline"][k] for k in ("compute_s", "memory_s", "collective_s")) + 1e-30),
        reverse=True,
    )[:n]


def run() -> list[tuple[str, float, str]]:
    out = []
    for r in load_records("single"):
        if r["status"] != "ok":
            if r["status"] == "skipped":
                out.append((f"roofline/{r['arch']}/{r['shape']}", 0.0, f"skipped:{r.get('reason','')[:60]}"))
            else:
                out.append((f"roofline/{r['arch']}/{r['shape']}", 0.0, f"ERROR:{r.get('error','')[:80]}"))
            continue
        t = r["roofline"]
        bound_us = t["roofline_bound_s"] * 1e6
        out.append(
            (
                f"roofline/{r['arch']}/{r['shape']}",
                bound_us,
                f"dom={t['dominant'].replace('_s','')};frac={t['roofline_fraction']:.2f}"
                f";compute={t['compute_s']:.3e};mem={t['memory_s']:.3e};coll={t['collective_s']:.3e}"
                f";useful={r.get('useful_flops_ratio') and round(r['useful_flops_ratio'],2)}",
            )
        )
    n_multi = len([r for r in load_records("multi") if r["status"] == "ok"])
    out.append(("roofline/multi-pod-cells-ok", 0.0, f"count={n_multi}"))
    return out
