"""Event-backend benchmark: throughput vs input sparsity.

Sweeps Bernoulli input spike density on the paper's MNIST-scale 256-128-10
LIF network and times ``run_int`` samples/sec for every registered inference
backend (``reference`` step-major, ``fused`` layer-major dense, ``event``
layer-major sparse) plus ``event-pallas`` -- the jit-compatible
fixed-capacity strategy, timed through one reused jitted forward.  The
point being measured is the event-driven contract: the event paths' work
scales with spike counts, so their advantage over the dense paths must
grow as the raster gets sparser -- mirroring how the modeled hardware
latency (``hw_model.latency_seconds``) scales with the same event counts.

Per density the report also records the event backend's chosen gather
budget (events-per-step capacity after lane rounding) and the modeled
hardware latency at the measured traffic, so the software speedup and the
modeled-hardware speedup can be compared side by side.

A ``composition`` section measures the two integrations that used to fall
back to dense: event x shard (``run_int_sharded`` with the pallas-strategy
event backend -- one compiled program across the mesh) and event x serve
(``SNNServeEngine`` admitting a sparse stream to the jitted
``"event-pallas"`` lane route).  Their ``samples_per_sec`` keys ride the
same ``--check-regression`` gate as the density sweep.

Emits ``BENCH_event.json`` at the repo root for the perf trajectory
(full-size runs only -- ``--fast`` smoke passes measure a reduced workload
and must not clobber the trajectory artifact; they write
``experiments/BENCH_event_fast.json`` instead, which is what CI uploads as
*that run's* measurement) and returns the harness's ``(name, us_per_call,
derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw_model
from repro.core import shard as shard_lib
from repro.core.backend import EventBackend, _round_capacity, get_backend
from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel
from repro.serve.snn_engine import SNNRequest, SNNServeEngine

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_event.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_event_fast.json"

DENSITIES = (0.02, 0.05, 0.10, 0.20, 0.40)
BACKENDS = ("reference", "fused", "event")


def _mnist_net(T: int) -> NetworkConfig:
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="bench-mnist-256-128-10",
    )


def _sparse_batches(net, n, T, batch, density, seed=0):
    """Bernoulli(density) rasters, time-major [T, batch, n_in] like a loader."""
    rng = np.random.default_rng(seed)
    raster = (rng.random((n, T, net.n_in)) < density).astype(np.int32)
    return [
        jnp.asarray(raster[i : i + batch].transpose(1, 0, 2))
        for i in range(0, n - batch + 1, batch)
    ]


def _make_fwd(net, qparams, spec):
    """One reusable forward per backend (name or configured instance).

    jit-compatible backends (including ``EventBackend(strategy="pallas")``)
    run through one reused jitted forward; the eager event strategies are
    host-driven (they size sparse budgets from concrete data and jit per
    layer internally), so they are timed as their consumers call them --
    the budget-sizing work is part of their real cost.
    """
    backend = get_backend(spec)
    if backend.jit_compatible:
        return jax.jit(lambda s: run_int(net, qparams, s, backend=backend).spike_counts)
    return lambda s: run_int(net, qparams, s, backend=backend).spike_counts


def _time_backends(net, qparams, batches, repeats: int, specs: dict) -> dict[str, float]:
    """Steady-state seconds per full pass over ``batches``, per backend.

    Backends are timed in *interleaved rounds* (ref, fused, event, ref, ...)
    and each backend reports its best round: background machine-load spikes
    then land on every backend equally and are discarded rather than biasing
    whichever backend ran during the noise (the usual ``timeit`` practice).
    """
    fwds = {name: _make_fwd(net, qparams, spec) for name, spec in specs.items()}
    for fwd in fwds.values():
        for b in batches:
            fwd(b).block_until_ready()  # compile/warm every shape + budget bucket
    best = {name: float("inf") for name in specs}
    for _ in range(repeats):
        for name, fwd in fwds.items():
            t0 = time.perf_counter()
            for b in batches:
                fwd(b).block_until_ready()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def run(fast: bool = False):
    n = 512 if not fast else 256
    T = 20 if not fast else 10
    repeats = 10 if not fast else 3
    batch = 256
    densities = DENSITIES if not fast else (0.05, 0.20)
    net = _mnist_net(T)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)

    rows = []
    report: dict = {
        "net": net.name, "samples": n, "T": T, "batch": batch,
        "jax_backend": jax.default_backend(),
        "densities": {},
    }

    for density in densities:
        batches = _sparse_batches(net, n, T, batch, density)
        k_max = max(int(jnp.max(jnp.sum(b, axis=-1))) for b in batches)
        budget = min(net.n_in, _round_capacity(k_max))
        entry: dict = {
            "input_density": density,
            "max_events_per_step": k_max,
            "event_budget": budget,
            "event_strategy": get_backend("event").resolved_strategy(),
            "backends": {},
        }
        specs: dict = {name: name for name in BACKENDS}
        specs["event-pallas"] = EventBackend("pallas", event_budget=max(1, k_max))
        seconds = _time_backends(net, qparams, batches, repeats, specs)
        for backend in specs:
            sec = seconds[backend]
            sps = len(batches) * batch / sec
            entry["backends"][backend] = {"seconds_per_pass": sec, "samples_per_sec": sps}
        ref_sps = entry["backends"]["reference"]["samples_per_sec"]
        ev_sps = entry["backends"]["event"]["samples_per_sec"]
        entry["event_speedup_vs_reference"] = ev_sps / ref_sps
        entry["event_pallas_speedup_vs_fused"] = (
            entry["backends"]["event-pallas"]["samples_per_sec"]
            / entry["backends"]["fused"]["samples_per_sec"]
        )

        # modeled hardware latency at the measured traffic, for the same story
        rec = run_int(net, qparams, batches[0], backend="event")
        lat = hw_model.latency_seconds(net, hw_model.EventTraffic.from_record(rec))
        entry["modeled_hw_latency_ms"] = lat * 1e3
        report["densities"][f"{density:.2f}"] = entry

        for backend in specs:
            b = entry["backends"][backend]
            if backend == "event":
                extra = (
                    f";speedup_vs_reference={entry['event_speedup_vs_reference']:.2f}x"
                    f";event_budget={budget}/{net.n_in}"
                )
            elif backend == "event-pallas":
                extra = f";speedup_vs_fused={entry['event_pallas_speedup_vs_fused']:.2f}x"
            else:
                extra = ""
            rows.append((
                f"event/density{density:.2f}-{backend}",
                b["seconds_per_pass"] * 1e6,
                f"samples_per_sec={b['samples_per_sec']:.1f}{extra}",
            ))

    report["composition"] = _composition(net, qparams, n, T, batch, repeats, rows)

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return rows


def _composition(net, qparams, n, T, batch, repeats, rows) -> dict:
    """event x shard and event x serve, both on the jitted sparse path.

    Before the pallas strategy these compositions fell back to dense: the
    sharded run abandoned the mesh for a serial eager pass, and the serving
    engine's jitted chunk advance integrated layer 0 densely.  Both are
    measured here at the serving admission density (5%) so the regression
    gate holds the *composed* programs fast, not just the leaf backend.
    """
    density = 0.05
    comp: dict = {"input_density": density}

    # --- event x shard: one compiled program across the mesh ---------------
    batches = _sparse_batches(net, n, T, batch, density)
    spikes = batches[0]
    k_max = max(1, int(jnp.max(jnp.sum(spikes, axis=-1))))
    backend = EventBackend("pallas", event_budget=k_max)
    dmesh = shard_lib.resolve_mesh("auto")

    def shard_pass():
        return shard_lib.run_int_sharded(
            net, qparams, spikes, dmesh, backend=backend
        ).spike_counts.block_until_ready()

    shard_pass()  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        shard_pass()
        best = min(best, time.perf_counter() - t0)
    comp["event_x_shard"] = {
        "n_shards": dmesh.n_shards,
        "event_strategy": backend.resolved_strategy(),
        "jit_compatible": backend.jit_compatible,
        "event_budget": backend.static_budget(net.n_in),
        "seconds_per_pass": best,
        "samples_per_sec": batch / best,
    }
    rows.append((
        "event/compose-shard",
        best * 1e6,
        f"samples_per_sec={batch / best:.1f};n_shards={dmesh.n_shards}",
    ))

    # --- event x serve: sparse stream through the jitted lane route --------
    n_req = min(batch, 64)
    rng = np.random.default_rng(7)
    rasters = [
        (rng.random((T, net.n_in)) < density).astype(np.int32) for _ in range(n_req)
    ]
    engine = SNNServeEngine(
        net, qparams, max_batch=16, backend=backend, sparse_admission_threshold=0.10
    )
    engine.warmup(T)
    best = float("inf")
    routes: dict = {}
    for _ in range(repeats):
        reqs = [SNNRequest(uid=i, raster=r) for i, r in enumerate(rasters)]
        t0 = time.perf_counter()
        done = engine.run(reqs)
        best = min(best, time.perf_counter() - t0)
        routes = {}
        for r in done:
            routes[r.route] = routes.get(r.route, 0) + 1
    comp["event_x_serve"] = {
        "n_requests": n_req,
        "event_budget": engine._event_budget,
        "routes": routes,
        "seconds_per_pass": best,
        "samples_per_sec": n_req / best,
    }
    rows.append((
        "event/compose-serve",
        best * 1e6,
        f"samples_per_sec={n_req / best:.1f};routes={routes}",
    ))
    return comp
