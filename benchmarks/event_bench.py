"""Event-backend benchmark: throughput vs input sparsity.

Sweeps Bernoulli input spike density on the paper's MNIST-scale 256-128-10
LIF network and times ``run_int`` samples/sec for every registered inference
backend (``reference`` step-major, ``fused`` layer-major dense, ``event``
layer-major sparse).  The point being measured is the event-driven
contract: the ``event`` backend's work scales with spike counts, so its
advantage over the dense paths must grow as the raster gets sparser --
mirroring how the modeled hardware latency (``hw_model.latency_seconds``)
scales with the same event counts.

Per density the report also records the event backend's chosen gather
budget (events-per-step capacity after lane rounding) and the modeled
hardware latency at the measured traffic, so the software speedup and the
modeled-hardware speedup can be compared side by side.

Emits ``BENCH_event.json`` at the repo root for the perf trajectory
(full-size runs only -- ``--fast`` smoke passes measure a reduced workload
and must not clobber the trajectory artifact; they write
``experiments/BENCH_event_fast.json`` instead, which is what CI uploads as
*that run's* measurement) and returns the harness's ``(name, us_per_call,
derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw_model
from repro.core.backend import _round_capacity, get_backend
from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_event.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_event_fast.json"

DENSITIES = (0.02, 0.05, 0.10, 0.20, 0.40)
BACKENDS = ("reference", "fused", "event")


def _mnist_net(T: int) -> NetworkConfig:
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="bench-mnist-256-128-10",
    )


def _sparse_batches(net, n, T, batch, density, seed=0):
    """Bernoulli(density) rasters, time-major [T, batch, n_in] like a loader."""
    rng = np.random.default_rng(seed)
    raster = (rng.random((n, T, net.n_in)) < density).astype(np.int32)
    return [
        jnp.asarray(raster[i : i + batch].transpose(1, 0, 2))
        for i in range(0, n - batch + 1, batch)
    ]


def _make_fwd(net, qparams, backend_name: str):
    """One reusable forward per backend.

    jit-compatible backends run through one reused jitted forward; the event
    backend is host-driven (it sizes sparse budgets from concrete data and
    jits per layer internally), so it is timed as its consumers call it --
    the budget-sizing work is part of its real cost.
    """
    backend = get_backend(backend_name)
    if backend.jit_compatible:
        return jax.jit(lambda s: run_int(net, qparams, s, backend=backend).spike_counts)
    return lambda s: run_int(net, qparams, s, backend=backend).spike_counts


def _time_backends(net, qparams, batches, repeats: int) -> dict[str, float]:
    """Steady-state seconds per full pass over ``batches``, per backend.

    Backends are timed in *interleaved rounds* (ref, fused, event, ref, ...)
    and each backend reports its best round: background machine-load spikes
    then land on every backend equally and are discarded rather than biasing
    whichever backend ran during the noise (the usual ``timeit`` practice).
    """
    fwds = {name: _make_fwd(net, qparams, name) for name in BACKENDS}
    for fwd in fwds.values():
        for b in batches:
            fwd(b).block_until_ready()  # compile/warm every shape + budget bucket
    best = {name: float("inf") for name in BACKENDS}
    for _ in range(repeats):
        for name, fwd in fwds.items():
            t0 = time.perf_counter()
            for b in batches:
                fwd(b).block_until_ready()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def run(fast: bool = False):
    n = 512 if not fast else 256
    T = 20 if not fast else 10
    repeats = 10 if not fast else 3
    batch = 256
    densities = DENSITIES if not fast else (0.05, 0.20)
    net = _mnist_net(T)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)

    rows = []
    report: dict = {
        "net": net.name, "samples": n, "T": T, "batch": batch,
        "jax_backend": jax.default_backend(),
        "densities": {},
    }

    for density in densities:
        batches = _sparse_batches(net, n, T, batch, density)
        k_max = max(int(jnp.max(jnp.sum(b, axis=-1))) for b in batches)
        budget = min(net.n_in, _round_capacity(k_max))
        entry: dict = {
            "input_density": density,
            "max_events_per_step": k_max,
            "event_budget": budget,
            "event_strategy": get_backend("event").resolved_strategy(),
            "backends": {},
        }
        seconds = _time_backends(net, qparams, batches, repeats)
        for backend in BACKENDS:
            sec = seconds[backend]
            sps = len(batches) * batch / sec
            entry["backends"][backend] = {"seconds_per_pass": sec, "samples_per_sec": sps}
        ref_sps = entry["backends"]["reference"]["samples_per_sec"]
        ev_sps = entry["backends"]["event"]["samples_per_sec"]
        entry["event_speedup_vs_reference"] = ev_sps / ref_sps

        # modeled hardware latency at the measured traffic, for the same story
        rec = run_int(net, qparams, batches[0], backend="event")
        lat = hw_model.latency_seconds(net, hw_model.EventTraffic.from_record(rec))
        entry["modeled_hw_latency_ms"] = lat * 1e3
        report["densities"][f"{density:.2f}"] = entry

        for backend in BACKENDS:
            b = entry["backends"][backend]
            extra = (
                f";speedup_vs_reference={entry['event_speedup_vs_reference']:.2f}x"
                f";event_budget={budget}/{net.n_in}"
                if backend == "event"
                else ""
            )
            rows.append((
                f"event/density{density:.2f}-{backend}",
                b["seconds_per_pass"] * 1e6,
                f"samples_per_sec={b['samples_per_sec']:.1f}{extra}",
            ))

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return rows
