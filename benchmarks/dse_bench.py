"""DSE benchmark: search-strategy quality + population-sweep throughput.

Three measurements around the Flex-plorer's pluggable search strategies:

* **Front quality at equal budget** -- on the MNIST-scale 256-128-10 LIF
  network (ATA-F hidden layer, so all three precision knobs are live and
  the space is 1800 configurations -- large relative to the budget; a
  feed-forward 2-knob space is small enough that any schedule enumerates
  it and every strategy trivially ties), run the population annealer to
  completion, then give NSGA-II (population 64 and 512) *the same
  evaluation budget* and compare 2-D Pareto-front hypervolume (accuracy x
  total hardware cost, both minimized as ``(1 - acc, hw)`` against the
  ``(1, 1)`` reference point) over each run's first ``budget`` unique
  evaluations.  The annealer optimises one scalar and concentrates near
  its optimum; NSGA-II's non-dominated/crowding selection spends the
  identical budget covering the trade-off curve, so its hypervolume
  should be >= the annealer's (recorded as ``nsga2_hv_ge_anneal``).
* **Resume fidelity** -- kill an NSGA-II search mid-generation (the sweep
  call raises after the snapshot of an earlier round) and resume from the
  checkpoint directory: the final front must be *identical* to the
  uninterrupted run's (``resume_front_identical``).
* **Sweep throughput** -- ``eval_int_population`` candidates/sec at
  population widths 64/512/2048 (16/64 in ``--fast``), at 1 vs 4 forced
  host devices.  The device-count comparison reuses the ``shard_bench``
  methodology: fresh worker subprocesses (``XLA_FLAGS`` must precede jax
  init) pinned to the single-threaded CPU runtime, interleaved rounds,
  best-of per config.  On a 1-core container the 4-device row measures
  sharding overhead, not speedup -- read it against ``shard_bench``'s
  process-parallel ceiling.

Emits ``BENCH_dse.json`` at the repo root (full runs; the committed perf
trajectory gated by ``--check-regression``) or
``experiments/BENCH_dse_fast.json`` (``--fast`` smoke; what CI uploads)
and returns the harness's ``(name, us_per_call, derived)`` rows.
"""

from __future__ import annotations

import itertools
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_dse.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_dse_fast.json"

#: Same per-device single-thread pinning as ``shard_bench`` (see there).
SINGLE_THREAD_FLAGS = (
    "--xla_cpu_use_thunk_runtime=false "
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
)
DEVICE_COUNTS = (1, 4)


# ---------------------------------------------------------------------------
# Pareto-front hypervolume (2-D, minimization, reference point (1, 1))
# ---------------------------------------------------------------------------


def _hypervolume(points, ref=(1.0, 1.0)) -> float:
    """Area dominated by ``points`` (minimized) up to ``ref``."""
    pts = sorted({(min(a, ref[0]), min(b, ref[1])) for a, b in points})
    hv, best_b = 0.0, ref[1]
    for a, b in pts:  # ascending first objective
        if b < best_b:
            hv += (ref[0] - a) * (best_b - b)
            best_b = b
    return hv


def _trace_points(trace, budget: int):
    """(1 - accuracy, hw_cost) of the first ``budget`` unique evaluations."""
    return [(1.0 - r["accuracy"], r["hw"]) for r in trace[:budget]]


# ---------------------------------------------------------------------------
# Worker: sweep throughput in a fresh process with forced device count
# ---------------------------------------------------------------------------


def _worker(cfg: dict) -> None:
    import jax

    from repro.core import shard as shard_lib
    from repro.core.network import NetworkConfig, init_float_params, quantize_params
    from repro.core.snn_layer import LayerConfig, NeuronModel
    from repro.data.snn_datasets import mnist_like
    from repro.snn.train import eval_int_population

    n_dev = len(jax.devices())
    assert n_dev == cfg["devices"], (n_dev, cfg)
    T = 6 if cfg["fast"] else 10
    B = 8  # eval batch: the sweep scales the *candidate* axis, keep data tiny
    rounds = 2

    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="dse-bench-mnist-256-128-10",
    )
    params = init_float_params(jax.random.PRNGKey(0), net)
    ds = mnist_like(n=B, T=T, seed=0)
    mesh = shard_lib.make_mesh()  # all (forced) devices; 1 device -> serial

    # distinct precision candidates, cycled to fill the sweep width; the
    # per-unique-config quantization is hoisted (the explorer caches it too)
    grid = list(itertools.product((2, 3, 4, 5, 6, 8, 10, 12, 16), (1, 2, 3, 4, 6, 8)))
    uniq = {
        bits: net.replace_precisions(w_bits=bits[0], leak_bits=bits[1]) for bits in grid
    }
    uniq_q = {bits: quantize_params(c, params)[0] for bits, c in uniq.items()}

    report = {"devices": n_dev, "widths": {}}
    for width in cfg["widths"]:
        cands = [uniq[grid[i % len(grid)]] for i in range(width)]
        qps = [uniq_q[grid[i % len(grid)]] for i in range(width)]

        def sweep():
            # stacking is part of the measured cost: it is what the
            # explorer pays per proposal round
            accs = eval_int_population(net, cands, qps, ds, batch_size=B, mesh=mesh)
            jax.block_until_ready(accs)

        sweep()  # compile
        best = float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            sweep()
            best = min(best, time.perf_counter() - t0)
        report["widths"][str(width)] = {
            "seconds_per_sweep": best,
            "candidates_per_sec": width / best,
        }
    print("DSE_WORKER_RESULT " + json.dumps(report))


def _spawn(devices: int, fast: bool, widths) -> subprocess.Popen:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} {SINGLE_THREAD_FLAGS}"
    )
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    cfg = json.dumps({"devices": devices, "fast": fast, "widths": list(widths)})
    return subprocess.Popen(
        [sys.executable, "-m", "benchmarks.dse_bench", "--worker", cfg],
        cwd=_ROOT,
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )


def _collect(proc: subprocess.Popen) -> dict:
    out, err = proc.communicate()
    for line in out.splitlines():
        if line.startswith("DSE_WORKER_RESULT "):
            return json.loads(line[len("DSE_WORKER_RESULT "):])
    raise RuntimeError(f"dse worker failed:\n{err[-2000:]}")


# ---------------------------------------------------------------------------
# Front quality + resume fidelity (in-process)
# ---------------------------------------------------------------------------


def _strategy_quality(fast: bool) -> tuple[dict, list]:
    import jax  # noqa: F401  (imported here so --worker runs never pay it twice)

    from repro.core.flexplorer import strategies as S
    from repro.core.flexplorer.explorer import EvalSpec, SearchSpec, SNNSearchSpace, explore_snn
    from repro.core.network import NetworkConfig
    from repro.core.snn_layer import LayerConfig, NeuronModel, Topology
    from repro.data.snn_datasets import mnist_like
    from repro.snn.train import train_snn

    # the qat_bench training recipe: enough timesteps/samples that accuracy
    # genuinely degrades at low precision (a chance-level net has a flat
    # accuracy axis and the front collapses to the min-hw point)
    T = 6 if fast else 20
    n = 128 if fast else 1536
    ds = mnist_like(n=n, T=T, seed=0)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(
                n_in=256, n_out=128, neuron=NeuronModel.LIF,
                topology=Topology.FF if fast else Topology.ATA_F,
                w_bits=6, u_bits=16,
            ),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="dse-bench-mnist-256-128-10",
    )
    res = train_snn(net, train, epochs=1 if fast else 6, batch_size=128, lr=2e-3)

    if fast:
        space = SNNSearchSpace(ff_bits=(2, 4, 6, 8), leak_bits=(2, 4, 8))
        pairs = ((16, 4),)
    else:
        bits = tuple(range(2, 17))
        space = SNNSearchSpace(
            ff_bits=bits, rec_bits=bits, leak_bits=(1, 2, 3, 4, 5, 6, 7, 8)
        )
        pairs = ((64, 40), (512, 16))
    ev = EvalSpec(batch=max(64, len(test.labels)))

    rows, report = [], {}
    report["train_acc"] = res.history[-1]["train_acc"]

    # -- anneal vs NSGA-II at equal budget, one pairing per population ------
    # Each pairing runs the annealer to completion, takes its evaluation
    # count as the shared budget, and caps NSGA-II at that budget.  The
    # annealer's eval_divisor picks the budget regime: it must stay well
    # under the 1800-configuration space (near-exhaustive budgets make
    # every strategy find the same front -- a degenerate tie) yet exceed
    # the NSGA population (a budget below the population ends inside the
    # random initial generation, before any selection pressure exists).
    # divisor 40 -> ~440 evals for pop 64; divisor 16 -> ~990 for pop 512.
    for pop, divisor in pairs:
        anneal_cfg = S.AnnealConfig(
            t_start=1.0, t_min=0.05, alpha=0.7, eval_divisor=divisor, seed=0
        )
        t0 = time.perf_counter()
        anneal = explore_snn(
            net, res.params, test,
            search=SearchSpec(space=space, config=anneal_cfg, population=8),
            evaluate=ev,
        )
        anneal_s = time.perf_counter() - t0
        budget = anneal.search.evaluations
        anneal_hv = _hypervolume(_trace_points(anneal.search.trace, budget))
        rows.append(
            (
                f"dse/front-anneal-b{budget}",
                anneal_s * 1e6,
                f"hv={anneal_hv:.4f};evals={budget}",
            )
        )

        cfg = S.NSGAConfig(population=pop, generations=64, seed=0)
        t0 = time.perf_counter()
        nsga = explore_snn(
            net, res.params, test,
            search=SearchSpec(
                space=space, strategy="nsga2", config=cfg, max_evaluations=budget
            ),
            evaluate=ev,
        )
        nsga_s = time.perf_counter() - t0
        # the final round may overshoot the cap; score both runs on exactly
        # the first `budget` unique evaluations for a fair comparison
        hv = _hypervolume(_trace_points(nsga.search.trace, budget))
        report[f"nsga2_pop{pop}"] = {
            "budget_evaluations": budget,
            "anneal": {
                "hypervolume": anneal_hv,
                "seconds": round(anneal_s, 2),
                "front_size": len(anneal.search.front),
            },
            "hypervolume": hv,
            "seconds": round(nsga_s, 2),
            "evaluations": min(budget, nsga.search.evaluations),
            "front_size": len(nsga.search.front),
            "hv_vs_anneal": hv / anneal_hv if anneal_hv else float("inf"),
        }
        rows.append(
            (
                f"dse/front-nsga2-pop{pop}",
                nsga_s * 1e6,
                f"hv={hv:.4f};anneal_hv={anneal_hv:.4f};ratio={hv / max(anneal_hv, 1e-12):.3f}",
            )
        )
    report["nsga2_hv_ge_anneal"] = all(
        report[f"nsga2_pop{p}"]["hypervolume"]
        >= report[f"nsga2_pop{p}"]["anneal"]["hypervolume"] - 1e-12
        for p, _ in pairs
    )

    # -- resume fidelity: kill mid-generation, resume, compare fronts -------
    from repro.snn import train as train_mod

    spec = dict(
        space=space,
        strategy="nsga2",
        config=S.NSGAConfig(population=16, generations=3, seed=1),
    )
    with tempfile.TemporaryDirectory() as tmp:
        full = explore_snn(
            net, res.params, test,
            search=SearchSpec(**spec, checkpoint_dir=f"{tmp}/full"),
            evaluate=ev,
        )
        real_sweep = train_mod.eval_int_population
        calls = {"n": 0}

        def dies(*args, **kw):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("killed mid-generation")
            return real_sweep(*args, **kw)

        import repro.core.flexplorer.explorer as explorer_mod

        explorer_mod.eval_int_population = dies
        try:
            try:
                explore_snn(
                    net, res.params, test,
                    search=SearchSpec(**spec, checkpoint_dir=f"{tmp}/killed"),
                    evaluate=ev,
                )
            except RuntimeError:
                pass
        finally:
            explorer_mod.eval_int_population = real_sweep
        resumed = explore_snn(
            net, res.params, test,
            search=SearchSpec(**spec, checkpoint_dir=f"{tmp}/killed"),
            evaluate=ev,
        )
    identical = (
        resumed.search.front == full.search.front
        and resumed.search.best == full.search.best
    )
    report["resume_front_identical"] = identical
    rows.append(("dse/resume-identical", 0.0, f"identical={identical};killed_at_call=2"))
    return report, rows


def run(fast: bool = False, device_counts=DEVICE_COUNTS, rounds: int | None = None):
    rounds = 1 if fast else (2 if rounds is None else rounds)
    widths = (16, 64) if fast else (64, 512, 2048)

    quality, rows = _strategy_quality(fast)

    # interleave device counts across rounds (shard_bench methodology)
    best: dict[int, dict] = {n: {} for n in device_counts}
    for _ in range(rounds):
        for n_dev in device_counts:
            res = _collect(_spawn(n_dev, fast, widths))
            for w, m in res["widths"].items():
                cur = best[n_dev].get(w)
                if cur is None or m["candidates_per_sec"] > cur["candidates_per_sec"]:
                    best[n_dev][w] = m

    report = {
        "workload": "dse-bench-mnist-256-128-10",
        "strategy_quality": quality,
        "sweep": {
            "widths": list(widths),
            "device_counts": list(device_counts),
            "xla_flags": SINGLE_THREAD_FLAGS,
            "host_cpu_count": os.cpu_count(),
            "by_devices": {str(n): best[n] for n in device_counts},
        },
    }
    for n_dev in device_counts:
        for w in widths:
            m = best[n_dev][str(w)]
            rows.append(
                (
                    f"dse/sweep-w{w}-{n_dev}dev",
                    m["seconds_per_sweep"] * 1e6,
                    f"cand_per_sec={m['candidates_per_sec']:.1f}",
                )
            )

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    rows.append(("dse/report-written", 0.0, str(out.relative_to(_ROOT))))
    return rows


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        _worker(json.loads(sys.argv[2]))
    else:
        for name, us, derived in run(fast="--fast" in sys.argv):
            print(f"{name},{us:.1f},{derived}")
