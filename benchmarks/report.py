"""Render EXPERIMENTS.md tables from the dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.report            # print all sections
    PYTHONPATH=src python -m benchmarks.report --section roofline
"""

from __future__ import annotations

import argparse
import json

from benchmarks.roofline import DRYRUN_DIR, load_records


def _fmt(x, fmt="{:.3e}"):
    return fmt.format(x) if x is not None else "—"


def dryrun_table() -> str:
    """Section Dry-run: per-cell compile evidence, both meshes."""
    out = [
        "| arch | shape | mesh | status | devices | peak mem/dev (XLA) | resident/dev (structural) | fits 16GB | lower+compile (s) | collective ops (surface) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("variant", "baseline") != "baseline":
            continue
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped | — | — | — | — | — | {r.get('reason','')[:60]} |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | {r.get('error','')[:60]} |")
            continue
        mem = r["memory"].get("peak_memory_in_bytes", 0) / 1e9
        cap = r["capacity_structural"]["total"] / 1e9
        nops = r["collectives_surface"]["n_ops"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['n_devices']} | {mem:.2f} GB | {cap:.2f} GB | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | {r['lower_s'] + r['compile_s']:.0f} | {nops} |"
        )
    return "\n".join(out)


def roofline_table() -> str:
    """Section Roofline: single-pod, baseline variant, all terms."""
    out = [
        "| arch | shape | kind | compute (s) | memory struct (s) | memory HLO (s) | collective (s) | dominant | compute frac | MODEL/HLO FLOPs | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "collective_s": "reshard: bf16/TP-only params, Megatron-EP, local CE head",
        "memory_s": "precision: int8/int4 weights (quant_matmul), int8 KV cache",
        "compute_s": "MXU utilisation: flash-attention kernel, larger per-chip batch",
    }
    for r in load_records("single"):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | skipped | — | — | {r.get('reason','')[:45]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | | |")
            continue
        t, th = r["roofline"], r["roofline_hlo_bytes"]
        tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{th['memory_s']:.3e} | {t['collective_s']:.3e} | {t['dominant'].replace('_s','')} | "
            f"{t['compute_s']/tot:.2f} | {_fmt(r.get('useful_flops_ratio'), '{:.2f}')} | {levers[t['dominant']]} |"
        )
    return "\n".join(out)


def variants_table() -> str:
    """Section Perf: every non-baseline compile, grouped by cell."""
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] == "ok":
            recs.append(r)
    cells = {}
    for r in recs:
        cells.setdefault((r["arch"], r["shape"], r["mesh"]), []).append(r)
    out = [
        "| arch | shape | variant | compute (s) | memory (s) | collective (s) | bound (s) | vs baseline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), rs in sorted(cells.items()):
        if mesh != "single" or len(rs) < 2:
            continue
        base = next((r for r in rs if r.get("variant", "baseline") == "baseline"), None)
        if base is None:
            continue
        base_bound = max(base["roofline"][k] for k in ("compute_s", "memory_s", "collective_s"))
        for r in sorted(rs, key=lambda r: r.get("variant", "baseline") != "baseline"):
            t = r["roofline"]
            bound = max(t[k] for k in ("compute_s", "memory_s", "collective_s"))
            speed = base_bound / bound if bound else float("inf")
            out.append(
                f"| {arch} | {shape} | {r.get('variant','baseline')} | {t['compute_s']:.3e} | "
                f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {bound:.3e} | {speed:.2f}x |"
            )
    return "\n".join(out)


SECTIONS = {"dryrun": dryrun_table, "roofline": roofline_table, "variants": variants_table}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=list(SECTIONS), default=None)
    args = ap.parse_args()
    names = [args.section] if args.section else list(SECTIONS)
    for n in names:
        print(f"\n### {n}\n")
        print(SECTIONS[n]())


if __name__ == "__main__":
    main()
