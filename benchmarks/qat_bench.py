"""QAT benchmark: post-training quantization vs quantization-aware training.

Two questions, on all three paper workloads (synthetic stand-ins):

* **Accuracy at aggressive precision** -- train a float network once, then
  for each w_bits in {2, 3, 4} compare (a) post-training quantization (PTQ:
  ``quantize_params`` of the float weights, the paper's flow) against (b)
  QAT fine-tuning at that precision (``qat.refine_candidates``, which
  fine-tunes all bit-width candidates in one vmapped program and keeps each
  candidate's best bit-exact-scored checkpoint -- epoch 0 is PTQ itself, so
  ``qat_acc >= ptq_acc`` structurally; the interesting number is the gap).
* **DSE front shift** -- run the Flex-plorer with ``refine_top_k`` and
  record the explored (PTQ) Pareto front vs the refined front, plus whether
  some refined point strictly dominates the unrefined front.

Also times the float vs QAT train step (samples/sec, steady state) -- the
``*_per_sec`` keys feed the nightly ``--check-regression`` gate.

Emits ``BENCH_qat.json`` at the repo root for the perf trajectory
(full-size runs only; ``--fast`` smoke passes write
``experiments/BENCH_qat_fast.json`` instead) and returns the harness's
``(name, us_per_call, derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core.flexplorer import annealer as annealer_lib
from repro.core.flexplorer.explorer import EvalSpec, RefineSpec, SearchSpec, SNNSearchSpace, explore_snn
from repro.core.network import NetworkConfig, init_float_params
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology
from repro.data.snn_datasets import dvs_like, mnist_like, shd_like
from repro.snn import qat as qat_lib
from repro.snn.surrogate import fast_sigmoid
from repro.snn.train import spike_count_loss, train_snn
from repro.train import optimizer as opt_lib

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_qat.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_qat_fast.json"

ANNEAL = annealer_lib.AnnealConfig(t_start=0.5, t_min=5e-2, alpha=0.6, eval_divisor=2, seed=0)


def _workloads(fast: bool):
    if fast:
        return [
            ("mnist_like", mnist_like(n=384, T=10, seed=0), Topology.FF, 64),
        ]
    return [
        ("mnist_like", mnist_like(n=1536, T=20, seed=0), Topology.FF, 128),
        ("shd_like", shd_like(n=1024, T=25, seed=1), Topology.FF, 128),
        ("dvs_like", dvs_like(n=1024, T=20, seed=2), Topology.ATA_F, 128),
    ]


def _net(name: str, ds, topology: Topology, hidden: int) -> NetworkConfig:
    T = ds.spikes.shape[1]
    n_in = ds.spikes.shape[2]
    mk = lambda i, o: LayerConfig(
        n_in=i, n_out=o, neuron=NeuronModel.LIF, topology=topology, w_bits=6, u_bits=16
    )
    return NetworkConfig(
        layers=(mk(n_in, hidden), mk(hidden, ds.n_classes)),
        n_steps=T,
        name=f"qat-{name}",
    )


def _time_train_steps(net, params, ds, qat_net, batch: int, repeats: int) -> tuple[float, float]:
    """Steady-state samples/sec of one jitted train step, float vs QAT."""
    spike_fn = fast_sigmoid(25.0)
    optimizer = opt_lib.adamw(1e-3)
    spikes, labels = next(ds.batches(batch))
    spikes, labels = jnp.asarray(spikes), jnp.asarray(labels)

    def step_fn(use_qat):
        from repro.core.network import run_float

        def loss(params, spikes, labels):
            if use_qat:
                rec = qat_lib.run_qat(qat_net, params, spikes, spike_fn)
            else:
                rec = run_float(net, params, spikes, spike_fn)
            return spike_count_loss(rec.spike_counts, labels)

        @jax.jit
        def step(params, opt_state, spikes, labels):
            grads = jax.grad(loss)(params, spikes, labels)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return opt_lib.apply_updates(params, updates), opt_state

        return step

    rates = []
    for use_qat in (False, True):
        step = step_fn(use_qat)
        opt_state = optimizer.init(params)
        p, s = params, opt_state
        p, s = step(p, s, spikes, labels)  # compile + warmup
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(repeats):
            p, s = step(p, s, spikes, labels)
        jax.block_until_ready(p)
        rates.append(repeats * int(labels.shape[0]) / (time.perf_counter() - t0))
    return rates[0], rates[1]


def _dominates_front(refined_points, front) -> bool:
    """True if some refined point dominates >= 1 unrefined-front point."""
    for r in refined_points:
        for f in front:
            if (
                r["hw_cost"] <= f["hw_cost"]
                and r["accuracy"] >= f["accuracy"]
                and (r["hw_cost"] < f["hw_cost"] or r["accuracy"] > f["accuracy"])
            ):
                return True
    return False


def run(fast: bool = False):
    rows = []
    report = {"qat_vs_ptq": {}, "dse_refine": {}, "train_step": {}, "meta": {}}
    w_bits_sweep = (3,) if fast else (2, 3, 4)
    float_epochs = 2 if fast else 6
    qat_epochs = 1 if fast else 6
    refine_epochs = 1 if fast else 4
    qat_lr = 1.5e-3

    for name, ds, topology, hidden in _workloads(fast):
        train, test = ds.split()
        net = _net(name, ds, topology, hidden)
        t0 = time.perf_counter()
        res = train_snn(net, train, epochs=float_epochs, batch_size=128, lr=2e-3)
        train_s = time.perf_counter() - t0

        candidates = [
            net.replace_precisions(w_bits=b, w_rec_bits=b) for b in w_bits_sweep
        ]
        t0 = time.perf_counter()
        rr = qat_lib.refine_candidates(
            net, candidates, res.params, train, test,
            epochs=qat_epochs, batch_size=128, lr=qat_lr, eval_batch=512,
        )
        qat_s = time.perf_counter() - t0

        cells = {}
        for k, b in enumerate(w_bits_sweep):
            ptq, qat = float(rr.base_acc[k]), float(rr.best_acc[k])
            cells[f"w{b}"] = {
                "ptq_acc": ptq,
                "qat_acc": qat,
                "delta_points": round(100 * (qat - ptq), 2),
            }
            rows.append(
                (
                    f"qat/{name}-w{b}",
                    qat_s * 1e6 / len(w_bits_sweep),
                    f"ptq={ptq:.4f};qat={qat:.4f}",
                )
            )
        report["qat_vs_ptq"][name] = cells
        report["meta"][name] = {
            "float_train_seconds": round(train_s, 2),
            "qat_refine_seconds": round(qat_s, 2),
            "float_final_train_acc": res.history[-1]["train_acc"],
        }

        # --- DSE: explored (PTQ) front vs train-in-the-loop refined front ---
        t0 = time.perf_counter()
        dse = explore_snn(
            net,
            res.params,
            test,
            search=SearchSpec(
                space=SNNSearchSpace(ff_bits=(2, 3, 4, 6), rec_bits=(2, 3, 4, 6), leak_bits=(3, 8)),
                config=ANNEAL,
            ),
            evaluate=EvalSpec(batch=512),
            refine=RefineSpec(
                top_k=1 if fast else 2,
                train_ds=train,
                epochs=refine_epochs,
                lr=qat_lr,
            ),
        )
        dse_s = time.perf_counter() - t0
        explored = dse.explored_front()
        refined_pts = [r.point() for r in dse.refined]
        dominates = _dominates_front(refined_pts, explored)
        report["dse_refine"][name] = {
            "explored_front": explored,
            "refined_points": refined_pts,
            "refined_front": dse.refined_front(),
            "refined_dominates_explored_front": dominates,
            "dse_seconds": round(dse_s, 2),
            "anneal_evaluations": dse.anneal.evaluations,
        }
        rows.append(
            (
                f"qat/{name}-dse-refine",
                dse_s * 1e6,
                f"dominates={dominates};refined={len(refined_pts)}",
            )
        )

    # --- train-step throughput (the nightly-gated *_per_sec metrics) ---
    name, ds, topology, hidden = _workloads(fast)[0]
    net = _net(name, ds, topology, hidden)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qat_net = net.replace_precisions(w_bits=3, w_rec_bits=3)
    f_rate, q_rate = _time_train_steps(
        net, params, ds.split()[0], qat_net, batch=128, repeats=3 if fast else 10
    )
    report["train_step"] = {
        "workload": name,
        "batch": 128,
        "float_train_samples_per_sec": round(f_rate, 1),
        "qat_train_samples_per_sec": round(q_rate, 1),
        "qat_overhead_x": round(f_rate / max(q_rate, 1e-9), 2),
    }
    rows.append(("qat/train-step-float", 1e6 * 128 / f_rate, f"samples_per_sec={f_rate:.1f}"))
    rows.append(("qat/train-step-qat", 1e6 * 128 / q_rate, f"samples_per_sec={q_rate:.1f}"))

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    rows.append(("qat/report-written", 0.0, str(out.relative_to(_ROOT))))
    return rows
