"""Serving benchmark: SNNServeEngine throughput + offered-load latency.

Measures the continuous-batching SNN service (``repro.serve.snn_engine``)
against the serial baseline it replaces -- one request at a time through a
reused jitted batch-1 ``run_int`` -- on the paper's MNIST-scale 256-128-10
LIF network:

* **closed loop**: all requests queued up front; samples/sec per lane-pool
  size (``max_batch``), with the engine/serial speedup recorded per batch
  (the acceptance number: >= 2x at batch >= 8);
* **offered load**: Poisson arrivals at fractions of the measured
  closed-loop capacity, replayed open-loop through ``SNNServeEngine.run``;
  reports p50/p99 request latency (queueing included) and achieved
  samples/sec -- the queueing-delay story serial execution cannot tell;
* **event admission**: a mixed sparse/dense request stream served with
  ``backend="event"``, recording how many requests the density-based
  admission policy routed to the sparse event path vs the lane pool.

Serial and engine passes are timed in interleaved rounds, best round per
contender (machine-load spikes land on both equally and are discarded),
mirroring ``event_bench``.

Emits ``BENCH_serve.json`` at the repo root for the perf trajectory
(full-size runs only -- ``--fast`` smoke passes measure a reduced workload
and write ``experiments/BENCH_serve_fast.json`` instead, which is what CI
uploads as *that run's* measurement) and returns the harness's ``(name,
us_per_call, derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel
from repro.data.snn_datasets import mnist_like
from repro.serve.snn_engine import SNNRequest, SNNServeEngine

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_serve.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_serve_fast.json"

BATCHES = (4, 8, 16)
LOAD_FRACTIONS = (0.5, 0.8, 0.95)


def _mnist_net(T: int) -> NetworkConfig:
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="serve-mnist-256-128-10",
    )


def _requests(rasters, arrivals=None):
    return [
        SNNRequest(uid=i, raster=r, arrival_s=0.0 if arrivals is None else arrivals[i])
        for i, r in enumerate(rasters)
    ]


def _serial_pass(fwd, rasters):
    for r in rasters:
        fwd(jnp.asarray(r[:, None, :], jnp.int32)).block_until_ready()


def run(fast: bool = False):
    n = 512 if not fast else 128
    T = 20 if not fast else 10
    repeats = 5 if not fast else 2
    batches = BATCHES if not fast else (8,)
    fractions = LOAD_FRACTIONS if not fast else (0.8,)

    net = _mnist_net(T)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)
    ds = mnist_like(n=n, T=T, seed=0)
    rasters = [ds.spikes[i] for i in range(n)]

    # serial baseline: the pre-service way to serve requests -- one jitted
    # batch-1 run_int per request, compiled once and reused
    fwd = jax.jit(lambda s: run_int(net, qparams, s).spike_counts)
    engines = {mb: SNNServeEngine(net, qparams, max_batch=mb) for mb in batches}

    # warm every contender (compile + chunk-program cache)
    _serial_pass(fwd, rasters[:2])
    for eng in engines.values():
        eng.warmup(T)
        eng.run(_requests(rasters[:4]))

    best_serial = float("inf")
    best_engine = {mb: float("inf") for mb in batches}
    for _ in range(repeats):  # interleaved rounds, best-of per contender
        t0 = time.perf_counter()
        _serial_pass(fwd, rasters)
        best_serial = min(best_serial, time.perf_counter() - t0)
        for mb, eng in engines.items():
            reqs = _requests(rasters)
            t0 = time.perf_counter()
            eng.run(reqs)
            best_engine[mb] = min(best_engine[mb], time.perf_counter() - t0)

    serial_sps = n / best_serial
    report: dict = {
        "net": net.name, "samples": n, "T": T,
        "jax_backend": jax.default_backend(),
        "serial_run_int": {"seconds_per_pass": best_serial, "samples_per_sec": serial_sps},
        "engine_closed_loop": {},
        "offered_load": {},
        "event_admission": {},
    }
    rows = [("serve/serial-run_int", best_serial * 1e6, f"samples_per_sec={serial_sps:.1f}")]

    for mb in batches:
        sps = n / best_engine[mb]
        report["engine_closed_loop"][str(mb)] = {
            "seconds_per_pass": best_engine[mb],
            "samples_per_sec": sps,
            "speedup_vs_serial": sps / serial_sps,
        }
        rows.append((
            f"serve/engine-batch{mb}",
            best_engine[mb] * 1e6,
            f"samples_per_sec={sps:.1f};speedup_vs_serial={sps / serial_sps:.2f}x",
        ))

    # offered load: Poisson arrivals at fractions of measured capacity
    mb_load = 8 if 8 in batches else batches[0]
    capacity = n / best_engine[mb_load]
    rng = np.random.default_rng(1)
    for frac in fractions:
        rate = capacity * frac
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
        eng = engines[mb_load]
        t0 = time.perf_counter()
        done = eng.run(_requests(rasters, arrivals))
        wall = time.perf_counter() - t0
        lat = np.asarray([r.latency_s for r in done]) * 1e3
        entry = {
            "offered_rate_per_sec": rate,
            "achieved_samples_per_sec": n / wall,
            "p50_latency_ms": float(np.percentile(lat, 50)),
            "p99_latency_ms": float(np.percentile(lat, 99)),
        }
        report["offered_load"][f"{frac:.2f}"] = entry
        rows.append((
            f"serve/load{frac:.2f}-batch{mb_load}",
            wall * 1e6,
            f"p50_ms={entry['p50_latency_ms']:.2f};p99_ms={entry['p99_latency_ms']:.2f}"
            f";samples_per_sec={entry['achieved_samples_per_sec']:.1f}",
        ))

    # event admission: mixed sparse/dense stream through the event policy
    rng = np.random.default_rng(2)
    sparse = [(rng.random((T, net.n_in)) < 0.02).astype(np.uint8) for _ in range(n // 4)]
    mixed = rasters[: n // 4] + sparse
    eng = SNNServeEngine(net, qparams, max_batch=mb_load, backend="event")
    eng.warmup(T)
    eng.run(_requests(mixed[:2] + sparse[:2]))  # warm the real budget buckets too
    reqs = _requests(mixed)
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    routes = sorted({r.route for r in done})
    n_event = sum(r.route.startswith("event") for r in done)
    report["event_admission"] = {
        "requests": len(mixed),
        "routed_to_event": n_event,
        "routed_to_lanes": len(mixed) - n_event,
        "routes": routes,
        "samples_per_sec": len(mixed) / wall,
    }
    rows.append((
        "serve/event-admission",
        wall * 1e6,
        f"event={n_event}/{len(mixed)};samples_per_sec={len(mixed) / wall:.1f}",
    ))

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return rows
