"""Serving benchmark: SNNServeEngine throughput + offered-load latency.

Measures the continuous-batching SNN service (``repro.serve.snn_engine``)
against the serial baseline it replaces -- one request at a time through a
reused jitted batch-1 ``run_int`` -- on the paper's MNIST-scale 256-128-10
LIF network:

* **closed loop**: all requests queued up front; samples/sec per lane-pool
  size (``max_batch``), with the engine/serial speedup recorded per batch
  (the ratio is host-dependent -- the regression gate tracks the absolute
  samples/sec, not the ratio);
* **offered load**: Poisson arrivals at fractions of the measured
  closed-loop capacity, replayed open-loop through ``SNNServeEngine.run``;
  reports p50/p99 request latency (queueing included) and achieved
  samples/sec -- the queueing-delay story serial execution cannot tell;
* **event admission**: a mixed sparse/dense request stream served with
  ``backend="event"``, recording how many requests the density-based
  admission policy routed to the sparse event path vs the lane pool;
* **QoS sweep**: mixed-priority traffic (10% critical / 30% standard /
  60% best-effort, per-class deadline SLOs) offered at 10-100x the
  measured closed-loop capacity -- far past saturation, where the
  front-line scheduler is the product.  Records per-class p50/p99
  latency, the degrade/reject/preempt counts, and critical-class SLO
  attainment: critical p99 must stay inside its deadline while
  best-effort absorbs the overload by degrading to the registered
  coarser precision tier or being rejected at admission;
* **streaming sessions**: N concurrent forever-streams
  (``repro.serve.streaming``) fed in fixed-size chunks round-robin --
  steps/sec, chunks/sec, sessions/sec and per-chunk p50/p99 at each
  concurrency, plus an eviction-churn variant where every stream's carry
  round-trips through the checkpoint store between chunks (the cost of
  parking idle streams on disk).

Serial and engine passes are timed in interleaved rounds, best round per
contender (machine-load spikes land on both equally and are discarded),
mirroring ``event_bench``.

Emits ``BENCH_serve.json`` at the repo root for the perf trajectory
(full-size runs only -- ``--fast`` smoke passes measure a reduced workload
and write ``experiments/BENCH_serve_fast.json`` instead, which is what CI
uploads as *that run's* measurement) and returns the harness's ``(name,
us_per_call, derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel
from repro.data.snn_datasets import mnist_like
from repro.serve.journal import Journal, recover
from repro.serve.scheduler import PrecisionTier, Priority, SchedPolicy
from repro.serve.snn_engine import SNNRequest, SNNServeEngine
from repro.serve.streaming import StreamConfig, StreamSessionManager

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_serve.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_serve_fast.json"

BATCHES = (4, 8, 16)
LOAD_FRACTIONS = (0.5, 0.8, 0.95)
QOS_MULTIPLIERS = (10, 30, 100)
# the three interactive classes of the overload sweep (STREAMING traffic is
# measured by the dedicated streaming section instead)
QOS_CLASSES = (Priority.CRITICAL, Priority.STANDARD, Priority.BEST_EFFORT)
# traffic mix for the overload sweep, indexed by Priority value
QOS_MIX = (0.10, 0.30, 0.60)  # critical / standard / best_effort
STREAM_CONCURRENCY = (64, 256, 1024)
STREAM_STEPS = 64  # raster steps each stream delivers
STREAM_CHUNK = 16  # steps per feed


def _mnist_net(T: int) -> NetworkConfig:
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="serve-mnist-256-128-10",
    )


def _requests(rasters, arrivals=None):
    return [
        SNNRequest(uid=i, raster=r, arrival_s=0.0 if arrivals is None else arrivals[i])
        for i, r in enumerate(rasters)
    ]


def _serial_pass(fwd, rasters):
    for r in rasters:
        fwd(jnp.asarray(r[:, None, :], jnp.int32)).block_until_ready()


def run(fast: bool = False):
    n = 512 if not fast else 128
    T = 20 if not fast else 10
    repeats = 5 if not fast else 2
    batches = BATCHES if not fast else (8,)
    fractions = LOAD_FRACTIONS if not fast else (0.8,)
    qos_mults = QOS_MULTIPLIERS if not fast else (10,)

    net = _mnist_net(T)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)
    ds = mnist_like(n=n, T=T, seed=0)
    rasters = [ds.spikes[i] for i in range(n)]

    # serial baseline: the pre-service way to serve requests -- one jitted
    # batch-1 run_int per request, compiled once and reused
    fwd = jax.jit(lambda s: run_int(net, qparams, s).spike_counts)
    engines = {mb: SNNServeEngine(net, qparams, max_batch=mb) for mb in batches}

    # warm every contender (compile + chunk-program cache)
    _serial_pass(fwd, rasters[:2])
    for eng in engines.values():
        eng.warmup(T)
        eng.run(_requests(rasters[:4]))

    best_serial = float("inf")
    best_engine = {mb: float("inf") for mb in batches}
    for _ in range(repeats):  # interleaved rounds, best-of per contender
        t0 = time.perf_counter()
        _serial_pass(fwd, rasters)
        best_serial = min(best_serial, time.perf_counter() - t0)
        for mb, eng in engines.items():
            reqs = _requests(rasters)
            t0 = time.perf_counter()
            eng.run(reqs)
            best_engine[mb] = min(best_engine[mb], time.perf_counter() - t0)

    serial_sps = n / best_serial
    report: dict = {
        "net": net.name, "samples": n, "T": T,
        "jax_backend": jax.default_backend(),
        "serial_run_int": {"seconds_per_pass": best_serial, "samples_per_sec": serial_sps},
        "engine_closed_loop": {},
        "offered_load": {},
        "event_admission": {},
        "qos_sweep": {},
    }
    rows = [("serve/serial-run_int", best_serial * 1e6, f"samples_per_sec={serial_sps:.1f}")]

    for mb in batches:
        sps = n / best_engine[mb]
        report["engine_closed_loop"][str(mb)] = {
            "seconds_per_pass": best_engine[mb],
            "samples_per_sec": sps,
            "speedup_vs_serial": sps / serial_sps,
        }
        rows.append((
            f"serve/engine-batch{mb}",
            best_engine[mb] * 1e6,
            f"samples_per_sec={sps:.1f};speedup_vs_serial={sps / serial_sps:.2f}x",
        ))

    # offered load: Poisson arrivals at fractions of measured capacity
    mb_load = 8 if 8 in batches else batches[0]
    capacity = n / best_engine[mb_load]
    rng = np.random.default_rng(1)
    for frac in fractions:
        rate = capacity * frac
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
        eng = engines[mb_load]
        t0 = time.perf_counter()
        done = eng.run(_requests(rasters, arrivals))
        wall = time.perf_counter() - t0
        lat = np.asarray([r.latency_s for r in done]) * 1e3
        entry = {
            "offered_rate_per_sec": rate,
            "achieved_samples_per_sec": n / wall,
            "p50_latency_ms": float(np.percentile(lat, 50)),
            "p99_latency_ms": float(np.percentile(lat, 99)),
        }
        report["offered_load"][f"{frac:.2f}"] = entry
        rows.append((
            f"serve/load{frac:.2f}-batch{mb_load}",
            wall * 1e6,
            f"p50_ms={entry['p50_latency_ms']:.2f};p99_ms={entry['p99_latency_ms']:.2f}"
            f";samples_per_sec={entry['achieved_samples_per_sec']:.1f}",
        ))

    # event admission: mixed sparse/dense stream through the event policy
    rng = np.random.default_rng(2)
    sparse = [(rng.random((T, net.n_in)) < 0.02).astype(np.uint8) for _ in range(n // 4)]
    mixed = rasters[: n // 4] + sparse
    eng = SNNServeEngine(net, qparams, max_batch=mb_load, backend="event")
    eng.warmup(T)
    eng.run(_requests(mixed[:2] + sparse[:2]))  # warm the real budget buckets too
    reqs = _requests(mixed)
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    routes = sorted({r.route for r in done})
    n_event = sum(r.route.startswith("event") for r in done)
    report["event_admission"] = {
        "requests": len(mixed),
        "routed_to_event": n_event,
        "routed_to_lanes": len(mixed) - n_event,
        "routes": routes,
        "samples_per_sec": len(mixed) / wall,
    }
    rows.append((
        "serve/event-admission",
        wall * 1e6,
        f"event={n_event}/{len(mixed)};samples_per_sec={len(mixed) / wall:.1f}",
    ))

    # QoS sweep: mixed-priority overload far past saturation.  Deadline SLOs
    # are set relative to the measured closed-loop capacity (base_wall = time
    # to serve the whole request set flat out), so the sweep measures the
    # scheduler, not this host's absolute speed.
    tier = PrecisionTier.from_params(net, params, w_bits=3, steps_fraction=0.5)
    qos_eng = SNNServeEngine(
        net, qparams, max_batch=mb_load,
        scheduler=SchedPolicy(), precision_tiers=[tier],
    )
    qos_eng.warmup(T)
    qos_eng.run(_requests(rasters[:4]))

    base_wall = n / capacity
    # per-class deadline SLOs, indexed by Priority value: critical must land
    # well inside the drain window; best-effort's sits at the drain window
    # itself, so under overload its keep-estimate fails and the deadline
    # sweep degrades (or rejects) it instead of queueing past the SLO
    slos = (0.5 * base_wall, 2.0 * base_wall, 1.0 * base_wall)
    # seed the service estimate from measured capacity (steady-state ticks
    # keep refining it): wall seconds per lane-step across the full pool
    qos_eng.metrics.seed_step_estimate(mb_load / (capacity * T))
    report["qos_sweep"] = {
        "mix": {p.name.lower(): QOS_MIX[p.value] for p in QOS_CLASSES},
        "deadline_slo_ms": {p.name.lower(): slos[p.value] * 1e3 for p in QOS_CLASSES},
        "degrade_tier": tier.name,
        "sweeps": {},
    }
    rng = np.random.default_rng(4)
    prios = rng.choice(3, size=n, p=QOS_MIX)
    for mult in qos_mults:
        rate = capacity * mult
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
        reqs = [
            SNNRequest(
                uid=i, raster=rasters[i], arrival_s=arrivals[i],
                priority=Priority(int(prios[i])), tenant=["a", "b"][i % 2],
                deadline_s=slos[int(prios[i])],
            )
            for i in range(n)
        ]
        m0 = qos_eng.metrics
        split0 = (m0.dispatch_s, m0.tick_s, m0.degrade_s)
        t0 = time.perf_counter()
        done = qos_eng.run(reqs)
        wall = time.perf_counter() - t0
        served = [r for r in done if r.status != "rejected"]

        classes = {}
        for p in QOS_CLASSES:
            sub = [r for r in reqs if r.priority is p]
            lat = np.asarray(
                [r.latency_s for r in sub if r.status != "rejected"]
            ) * 1e3
            classes[p.name.lower()] = {
                "requests": len(sub),
                "completed": sum(r.status == "completed" for r in sub),
                "degraded": sum(r.status == "degraded" for r in sub),
                "rejected": sum(r.status == "rejected" for r in sub),
                "p50_latency_ms": float(np.percentile(lat, 50)) if lat.size else None,
                "p99_latency_ms": float(np.percentile(lat, 99)) if lat.size else None,
            }
        crit = [r for r in reqs if r.priority is Priority.CRITICAL]
        in_slo = sum(
            r.status != "rejected" and r.latency_s <= slos[Priority.CRITICAL.value]
            for r in crit
        )
        crit_p99 = classes["critical"]["p99_latency_ms"]
        entry = {
            "offered_rate_per_sec": rate,
            "served_per_sec": len(served) / wall,
            "critical_slo_attainment": in_slo / max(len(crit), 1),
            "critical_p99_meets_slo": bool(
                crit_p99 is not None
                and crit_p99 <= slos[Priority.CRITICAL.value] * 1e3
            ),
            "preempted_requests": sum(r.preemptions > 0 for r in reqs),
            "classes": classes,
            # scheduling vs compute attribution for this sweep
            "dispatch_s": qos_eng.metrics.dispatch_s - split0[0],
            "tick_s": qos_eng.metrics.tick_s - split0[1],
            "degrade_s": qos_eng.metrics.degrade_s - split0[2],
        }
        report["qos_sweep"]["sweeps"][f"{mult}x"] = entry
        rows.append((
            f"serve/qos-{mult}x-batch{mb_load}",
            wall * 1e6,
            f"crit_p99_ms={crit_p99:.2f};crit_slo_attain={entry['critical_slo_attainment']:.3f}"
            f";degraded={sum(r.status == 'degraded' for r in reqs)}"
            f";rejected={sum(r.status == 'rejected' for r in reqs)}"
            f";served_per_sec={entry['served_per_sec']:.1f}",
        ))

    # streaming sessions: concurrent forever-streams fed in chunks.  Chunk
    # latency (feed -> chunk served, queueing included) comes from the
    # engine's STREAMING-class rolling window; one engine per concurrency so
    # the windows do not bleed across runs.
    stream_concurrency = STREAM_CONCURRENCY if not fast else (32,)
    stream_steps = STREAM_STEPS if not fast else 16
    report["streaming"] = {}

    def _stream_run(n_streams, evict_dir=None):
        eng = SNNServeEngine(net, qparams, max_batch=mb_load, tick_stride=16)
        eng.warmup(2 * STREAM_CHUNK)
        mgr = StreamSessionManager(
            eng,
            checkpoint_dir=evict_dir,
            config=StreamConfig(window=2 * STREAM_CHUNK, stride=STREAM_CHUNK,
                                idle_budget=None),
        )
        for i in range(n_streams):
            mgr.open(f"s{i}")
        # tiny warm pass so the first measured chunk is not a compile
        mgr.feed("s0", rasters[0][:STREAM_CHUNK])
        mgr.pump()
        t0 = time.perf_counter()
        for lo in range(0, stream_steps, STREAM_CHUNK):
            for i in range(n_streams):
                raster = rasters[i % len(rasters)]
                chunk = np.tile(raster, (2, 1))[lo % T:, :][:STREAM_CHUNK]
                mgr.feed(f"s{i}", chunk)
            mgr.pump()
            if evict_dir is not None:  # churn: park every carry on disk
                for i in range(n_streams):
                    mgr.evict(f"s{i}")
        wall = time.perf_counter() - t0
        lat = eng.metrics.latency[Priority.STREAMING]
        now = time.perf_counter()
        return {
            "streams": n_streams,
            "steps_per_sec": n_streams * stream_steps / wall,
            "chunks_per_sec": n_streams * (stream_steps // STREAM_CHUNK) / wall,
            "sessions_per_sec": n_streams / wall,
            "chunk_p50_ms": lat.percentile(50, now) * 1e3,
            "chunk_p99_ms": lat.percentile(99, now) * 1e3,
            "evictions": eng.metrics.counters["sessions_evicted"],
            "restores": eng.metrics.counters["sessions_restored"],
        }, wall

    for n_streams in stream_concurrency:
        entry, wall = _stream_run(n_streams)
        report["streaming"][f"{n_streams}"] = entry
        rows.append((
            f"serve/stream-{n_streams}",
            wall * 1e6,
            f"steps_per_sec={entry['steps_per_sec']:.0f}"
            f";chunk_p50_ms={entry['chunk_p50_ms']:.2f}"
            f";chunk_p99_ms={entry['chunk_p99_ms']:.2f}",
        ))

    churn_streams = stream_concurrency[min(1, len(stream_concurrency) - 1)]
    with tempfile.TemporaryDirectory(prefix="neura-stream-bench-") as tmp:
        entry, wall = _stream_run(churn_streams, evict_dir=pathlib.Path(tmp))
    report["streaming"]["eviction_churn"] = entry
    rows.append((
        f"serve/stream-churn-{churn_streams}",
        wall * 1e6,
        f"steps_per_sec={entry['steps_per_sec']:.0f}"
        f";evictions={entry['evictions']};restores={entry['restores']}",
    ))

    # recovery: the cost of crash safety.  (a) journal overhead -- the same
    # closed-loop pass with every admission/completion written through the
    # WAL (fsync-batched), gated as absolute samples/sec; (b) replay cost --
    # recover() + apply() over synthetic WALs of growing length, gated as
    # records/sec so a recovery-path slowdown trips the same gate the serve
    # paths use.
    report["recovery"] = {"journal_overhead": {}, "replay": {}}
    plain_sps = n / best_engine[mb_load]
    with tempfile.TemporaryDirectory(prefix="neura-bench-wal-") as tmp:
        jeng = SNNServeEngine(net, qparams, max_batch=mb_load)
        jeng.warmup(T)
        jeng.journal = Journal(pathlib.Path(tmp) / "wal", fsync_every=16)
        jeng.run(_requests(rasters[:4]))
        best_journaled = float("inf")
        for _ in range(repeats):
            reqs = _requests(rasters)
            t0 = time.perf_counter()
            jeng.run(reqs)
            best_journaled = min(best_journaled, time.perf_counter() - t0)
        jeng.journal.close()
        journaled_sps = n / best_journaled
        report["recovery"]["journal_overhead"] = {
            "journaled_samples_per_sec": journaled_sps,
            "plain_samples_per_sec": plain_sps,
            "overhead_fraction": max(0.0, 1.0 - journaled_sps / plain_sps),
        }
        rows.append((
            f"serve/journal-batch{mb_load}",
            best_journaled * 1e6,
            f"journaled_samples_per_sec={journaled_sps:.1f}"
            f";overhead={report['recovery']['journal_overhead']['overhead_fraction'] * 100:.1f}%",
        ))

    wal_lengths = (256, 1024, 4096) if not fast else (64, 256)
    for k in wal_lengths:
        with tempfile.TemporaryDirectory(prefix="neura-bench-wal-") as tmp:
            with Journal(tmp, fsync_every=64) as j:
                for i in range(k // 2):  # half the admissions completed
                    j.append("submit", arrays={"raster": rasters[i % n]},
                             uid=i, priority=1, tenant="default", deadline_s=None)
                    if i % 2 == 0:
                        j.append("done", uid=i, status="completed")
            n_records = k // 2 + (k // 2 + 1) // 2
            t0 = time.perf_counter()
            state = recover(tmp)
            fresh = SNNServeEngine(net, qparams, max_batch=mb_load)
            summary = state.apply(fresh)
            wall = time.perf_counter() - t0
        entry = {
            "wal_records": n_records,
            "outstanding_requests": summary["requests_resubmitted"],
            "recovery_s": wall,
            "replay_records_per_sec": n_records / wall,
        }
        report["recovery"]["replay"][str(n_records)] = entry
        rows.append((
            f"serve/recover-wal{n_records}",
            wall * 1e6,
            f"replay_records_per_sec={entry['replay_records_per_sec']:.0f}"
            f";resubmitted={entry['outstanding_requests']}",
        ))

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return rows
