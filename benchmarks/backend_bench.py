"""Backend benchmark: simulator throughput and DSE candidate rate.

Two measurements on the paper's MNIST-scale 256-128-10 LIF network:

* ``eval_int`` throughput (samples/sec) per inference backend
  (``reference`` step-major vs ``fused`` layer-major kernel path), steady
  state (compile excluded by a warmup pass).
* Flex-plorer DSE candidates/sec, serial annealer vs population mode.
  Serial mode pays one jit trace+compile per precision candidate (every
  candidate is a fresh closed-over ``NetworkConfig``); population mode
  scores whole proposal batches through one reused vmapped program -- the
  compile cost is the thing being benchmarked, so it is *included* here.

Emits ``BENCH_backend.json`` at the repo root for the perf trajectory
(full-size runs only -- ``--fast`` smoke passes measure a reduced workload
and must not clobber the trajectory artifact; they write
``experiments/BENCH_backend_fast.json`` instead, which is what CI uploads
as *that run's* measurement) and returns the harness's ``(name,
us_per_call, derived)`` rows.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core.backend import available_backends
from repro.core.flexplorer import annealer as annealer_lib
from repro.core.flexplorer.explorer import EvalSpec, SearchSpec, SNNSearchSpace, explore_snn
from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel
from repro.data.snn_datasets import mnist_like

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_backend.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_backend_fast.json"

ANNEAL = annealer_lib.AnnealConfig(t_start=1.0, t_min=5e-3, alpha=0.6, eval_divisor=2, seed=0)
SPACE = SNNSearchSpace(ff_bits=(4, 5, 6, 8, 12, 16), leak_bits=(2, 3, 4, 8))


def _mnist_net(T: int) -> NetworkConfig:
    return NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="bench-mnist-256-128-10",
    )


def _time_eval(net, qparams, ds, backend: str, repeats: int) -> float:
    """Steady-state seconds per full-dataset pass through one jitted forward.

    The forward is jitted once and reused across timed passes (``eval_int``
    itself builds a fresh closure per call, which would re-pay trace+compile
    every repeat and swamp the simulator time being compared).
    """
    fwd = jax.jit(
        lambda spikes: run_int(net, qparams, spikes, backend=backend).predictions()
    )
    batches = [jnp.asarray(s) for s, _ in ds.batches(256)]
    for b in batches:
        fwd(b).block_until_ready()  # compile (once per batch shape)
    t0 = time.perf_counter()
    for _ in range(repeats):
        for b in batches:
            fwd(b).block_until_ready()
    return (time.perf_counter() - t0) / repeats


def _time_dse(net, params, ds, population: int) -> tuple[float, int, int]:
    """Returns (seconds, total evaluations, search-requested evaluations).

    Both runs execute the identical anneal schedule, so the wall-clock ratio
    is the search-for-search speedup; total evaluations additionally count
    the population mode's speculative lane-fill scores (real bit-exact
    candidate evaluations, but not walker-requested ones).
    """
    jax.clear_caches()  # serial's per-candidate compile cost is the workload
    t0 = time.perf_counter()
    result = explore_snn(
        net, params, ds,
        search=SearchSpec(space=SPACE, config=ANNEAL, population=population),
        evaluate=EvalSpec(batch=256),
    )
    sec = time.perf_counter() - t0
    return sec, result.anneal.evaluations, result.anneal.requested_evaluations


def run(fast: bool = False, population: int = 8):
    n = 512 if not fast else 256
    T = 20 if not fast else 10
    repeats = 10 if not fast else 3
    ds = mnist_like(n=n, T=T, seed=0)
    net = _mnist_net(T)
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)

    rows = []
    report: dict = {
        "net": net.name, "samples": n, "T": T,
        "jax_backend": jax.default_backend(),
        "backends": available_backends(),
        "eval_int": {}, "dse": {},
    }

    for backend in ("reference", "fused"):
        sec = _time_eval(net, qparams, ds, backend, repeats)
        sps = n / sec
        report["eval_int"][backend] = {"seconds_per_pass": sec, "samples_per_sec": sps}
        rows.append((f"backend/eval_int-{backend}", sec * 1e6, f"samples_per_sec={sps:.1f}"))

    serial_s, serial_evals, _ = _time_dse(net, params, ds, population=0)
    pop_s, pop_evals, pop_requested = _time_dse(net, params, ds, population=population)
    serial_cps = serial_evals / serial_s
    pop_cps = pop_evals / pop_s
    speedup = pop_cps / serial_cps
    wallclock_speedup = serial_s / pop_s  # identical anneal schedule both runs
    report["dse"] = {
        "serial": {"seconds": serial_s, "evaluations": serial_evals, "candidates_per_sec": serial_cps},
        "population": {
            "seconds": pop_s, "evaluations": pop_evals,
            "requested_evaluations": pop_requested,
            "candidates_per_sec": pop_cps, "population": population,
        },
        "population_speedup_candidates_per_sec": speedup,
        "search_wallclock_speedup": wallclock_speedup,
    }
    rows.append(("backend/dse-serial", serial_s * 1e6, f"cand_per_sec={serial_cps:.2f};evals={serial_evals}"))
    rows.append((
        f"backend/dse-population{population}", pop_s * 1e6,
        f"cand_per_sec={pop_cps:.2f};evals={pop_evals}(requested={pop_requested})"
        f";speedup={speedup:.2f}x;wallclock_speedup={wallclock_speedup:.2f}x",
    ))

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return rows
