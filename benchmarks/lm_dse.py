"""Flex-plorer at LM scale (beyond-paper): serving-precision DSE.

The paper's annealer drives per-layer-group weight precision for LM decode.
Knobs: attention-projection bits and MLP/SSM bits in {4, 8, 16}.  Costs:

  hw term  -- structural decode-memory seconds (params stream at the chosen
              widths; KV cache unchanged), normalised by the bf16 baseline --
              the decode_32k cells are memory-bound, so this is 1:1 with
              step time.
  acc term -- end-to-end logit divergence: mean |logits_q - logits_fp|
              (normalised) on a held batch through the *reduced* config with
              real quantized weights -- the LM analogue of the paper's
              bit-exact hardware-aware accuracy.

Emits the chosen precision per architecture + the full anneal trace.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.flexplorer import annealer as annealer_lib
from repro.core.precision import PrecisionPolicy, quantize_tree
from repro.distributed.structural import structural_bytes
from repro.models.registry import SHAPES, ShapeSpec, get_arch

ATTN_RE = r"(wq|wk|wv|wo)$"
MLP_RE = r"(w_gate|w_up|w_down|in_proj|out_proj)$"


def _policy(attn_bits: int, mlp_bits: int) -> PrecisionPolicy:
    rules = []
    if attn_bits < 16:
        rules.append((ATTN_RE, attn_bits))
    if mlp_bits < 16:
        rules.append((MLP_RE, mlp_bits))
    return PrecisionPolicy(rules=tuple(rules))


def _decode_mem_seconds(arch, quant_bits):
    s = structural_bytes(arch, SHAPES["decode_32k"], quant_bits=quant_bits)
    return s["total"] / 819e9


def run(archs=("gemma2-27b", "qwen2-moe-a2.7b", "mamba2-780m"), c_hw: float = 0.6) -> list[tuple[str, float, str]]:
    out = []
    tiny = ShapeSpec("dse_eval", 128, 2, "train")
    for name in archs:
        t0 = time.time()
        arch = get_arch(name)
        cfg = arch.reduced_config
        key = jax.random.PRNGKey(0)
        params = arch.init_params(key, cfg)
        batch = arch.input_concrete(key, tiny, cfg)

        from repro.models import transformer as tfm, whisper as whs

        def logits_of(p):
            if arch.family == "audio":
                return whs.whisper_forward(cfg, p, batch["audio_frames"], batch["tokens"])
            return tfm.forward(cfg, p, batch["tokens"], vision_embeds=batch.get("vision_embeds"))[0]

        base_logits = np.asarray(jax.jit(logits_of)(params), np.float32)
        base_mem = _decode_mem_seconds(arch, None)
        norm = float(np.mean(np.abs(base_logits))) + 1e-9

        div_cache = {}

        def acc_fn(cand):
            attn_bits, mlp_bits = cand
            if cand not in div_cache:
                qp = quantize_tree(params, _policy(attn_bits, mlp_bits))
                ql = np.asarray(jax.jit(logits_of)(qp), np.float32)
                div = float(np.mean(np.abs(ql - base_logits))) / norm
                div_cache[cand] = max(0.0, 1.0 - div)  # pseudo-accuracy in [0,1]
            return div_cache[cand]

        def hw_fn(cand):
            attn_bits, mlp_bits = cand
            # dominant stream = the smaller of the two groups' widths applies
            # to its share of parameters; approximate with the mean bits
            mean_bits = (attn_bits + mlp_bits) / 2
            q = 4 if mean_bits <= 5 else (8 if mean_bits <= 12 else None)
            return c_hw * _decode_mem_seconds(arch, q) / base_mem

        result = annealer_lib.simulated_annealing(
            {"attn_bits": [4, 8, 16], "mlp_bits": [4, 8, 16]},
            hw_fn,
            acc_fn,
            lambda a: (1 - c_hw) * (1.0 - a),
            annealer_lib.AnnealConfig(t_start=0.5, t_min=0.02, alpha=0.6, eval_divisor=2, seed=0),
        )
        b = result.best_breakdown
        us = (time.time() - t0) * 1e6
        mem_q = _decode_mem_seconds(arch, 8 if b["attn_bits"] >= 8 or b["mlp_bits"] >= 8 else 4)
        out.append(
            (
                f"lm_dse/{name}",
                us,
                f"attn_bits={b['attn_bits']};mlp_bits={b['mlp_bits']}"
                f";logit_fidelity={b['accuracy']:.4f};decode_mem_x={mem_q/base_mem:.2f}"
                f";evals={result.evaluations}",
            )
        )
    return out
