"""Paper Fig. 11: DSE cost landscape for an ATA-F network on DVS.

User spec from the figure caption: LIF, ATA-F, layers [256, 200->(128), 11],
ff bits {4, 8, 12, 16}, rec bits {4, 8, 12, 16}, leak precision {3, 8};
weights HW=0.5 / ACC=0.5, LUT=0.33 / BRAM=0.34 / FF=0.33.
(Hidden width reduced 200 -> 128 to respect the 256-neuron/core cap with
margin at smoke scale; grid kept identical.)

Emits the full candidate list sorted by total cost (the figure's x-axis) to
``experiments/fig11_dse.csv`` plus the annealer's chosen point.
"""

from __future__ import annotations

import csv
import pathlib
import time

from repro.core.flexplorer import annealer as annealer_lib
from repro.core.flexplorer import cost as cost_lib
from repro.core.flexplorer.explorer import EvalSpec, SearchSpec, SNNSearchSpace, explore_snn
from repro.core.network import NetworkConfig
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology
from repro.data.snn_datasets import dvs_like
from repro.snn.train import train_snn

OUT = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "fig11_dse.csv"


def run(epochs: int = 5, T: int = 20, backend: str = "reference", population: int = 0) -> list[tuple[str, float, str]]:
    t0 = time.time()
    ds = dvs_like(n=1200, T=T, seed=2)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, topology=Topology.ATA_F, w_bits=8, u_bits=16),
            LayerConfig(n_in=128, n_out=11, neuron=NeuronModel.LIF, topology=Topology.FF, w_bits=8, u_bits=16),
        ),
        n_steps=T,
        name="fig11-ataf-dvs",
    )
    res_train = train_snn(net, train, epochs=epochs, batch_size=128, lr=2e-3)
    weights = cost_lib.CostWeights(c_hw=0.5, c_acc=0.5, c_lut=0.33, c_ff=0.33, c_bram=0.34)
    result = explore_snn(
        net,
        res_train.params,
        test,
        search=SearchSpec(
            space=SNNSearchSpace(ff_bits=(4, 8, 12, 16), rec_bits=(4, 8, 12, 16), leak_bits=(3, 8)),
            weights=weights,
            config=annealer_lib.AnnealConfig(t_start=1.0, t_min=0.02, alpha=0.6, eval_divisor=3, seed=0),
            population=population,
        ),
        evaluate=EvalSpec(backend=backend),
    )
    # figure data: every evaluated candidate, sorted by total cost
    rows = sorted(result.anneal.trace, key=lambda r: r["total"])
    OUT.parent.mkdir(parents=True, exist_ok=True)
    with OUT.open("w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["ff_bits", "rec_bits", "leak_bits", "total_cost", "hw_cost", "acc_cost", "accuracy"])
        for r in rows:
            w.writerow([r["cfg"].get("ff_bits"), r["cfg"].get("rec_bits"), r["cfg"].get("leak_bits"),
                        f"{r['total']:.5f}", f"{r['hw']:.5f}", f"{r['acc_cost']:.5f}", f"{r['accuracy']:.4f}"])
    chosen = result.anneal.best_breakdown
    us = (time.time() - t0) * 1e6
    derived = (
        f"chosen_ff={chosen['ff_bits']};rec={chosen.get('rec_bits')};leak={chosen['leak_bits']}"
        f";acc={chosen['accuracy']:.4f};evals={result.anneal.evaluations}"
        f";paper_choice=ff8_rec8_leak8;csv={OUT.name}"
    )
    return [("fig11/dse-ataf-dvs", us, derived)]
