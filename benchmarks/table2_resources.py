"""Paper Table 2: resources / latency / power / energy for the MNIST design.

Trains the paper's 256-128-10 LIF network, quantizes to 6-bit weights, runs
the bit-exact simulator to get real event statistics, and evaluates the
hardware models (latency at 60 MHz, LUT/FF/BRAM, power, energy/image,
energy/synapse) against the paper's reported design point:

    1623 logic cells, 934 LUT, 689 FF, 7 BRAM, 111 mW, 1.1 ms, 0.12 mJ,
    3.5 nJ/syn, 97.23 % accuracy (real MNIST).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import hw_model
from repro.core.network import NetworkConfig, quantize_params
from repro.core.snn_layer import LayerConfig
from repro.data.snn_datasets import mnist_like
from repro.snn.train import eval_int, train_snn

PAPER = {
    "logic_cells": 1623, "lut": 934, "ff": 689, "bram": 7,
    "power_w": 0.111, "latency_ms": 1.1, "e_img_mj": 0.12, "acc": 0.9723,
}


def run(epochs: int = 10, T: int = 25) -> list[tuple[str, float, str]]:
    t0 = time.time()
    # max_rate 0.18 approximates the paper's sparse rate coding (the
    # event-driven latency model scales linearly with input event rate)
    ds = mnist_like(n=2048, T=T, seed=0, max_rate=0.18)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, w_bits=6, u_bits=8, beta=0.95),
            LayerConfig(n_in=128, n_out=10, w_bits=6, u_bits=8, beta=0.95),
        ),
        n_steps=T,
        name="mnist-256-128-10",
    )
    res = train_snn(net, train, epochs=epochs, batch_size=128, lr=2e-3, rate_reg=2e-4)
    qparams, _ = quantize_params(net, res.params)
    acc, stats = eval_int(net, qparams, test, return_stats=True)

    r = hw_model.network_resources(net)
    # scale event statistics to the paper's 100-step window for latency
    scale = 100 / T
    in_ev = np.repeat(stats["input_events_per_step"], int(scale))[:100]
    layer_ev = [np.repeat(e, int(scale))[:100] for e in stats["layer_events_per_step"]]
    lat = hw_model.latency_seconds(net, in_ev, layer_ev)
    total_events = float(in_ev.sum() + sum(e.sum() for e in layer_ev))
    e_img = hw_model.energy_per_image(net, lat, total_events)
    p = hw_model.power_watts(net, total_events / lat)
    n_syn = 256 * 128 + 128 * 10
    us = (time.time() - t0) * 1e6

    derived = (
        f"acc={acc:.4f}(paper {PAPER['acc']});logic={r.logic_cells:.0f}({PAPER['logic_cells']});"
        f"lut={r.lut:.0f}({PAPER['lut']});ff={r.ff:.0f}({PAPER['ff']});bram={r.bram}({PAPER['bram']});"
        f"lat_ms={lat*1e3:.2f}({PAPER['latency_ms']});power_w={p:.3f}({PAPER['power_w']});"
        f"e_img_mj={e_img*1e3:.3f}({PAPER['e_img_mj']});e_syn_nj={e_img/n_syn*1e9:.2f}(3.5)"
    )
    return [("table2/mnist-256-128-10", us, derived)]
