"""Shard benchmark: multi-device scaling of eval, DSE fan-out, and serving.

Measures the ``repro.core.shard`` execution layer at 1/2/4 forced host
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` -- the
flag must be set before jax initialises, so every measurement runs in a
fresh worker subprocess):

* ``eval``  -- ``run_int_sharded`` samples/sec, sample axis split across
  the mesh (the ``eval_int`` hot path);
* ``dse``   -- ``run_int_population_sharded`` candidates/sec, candidate
  axis split across the mesh (the population Flex-plorer's fan-out);
* ``serve`` -- ``SNNServeEngine(data_parallel=N)`` served samples/sec,
  lane pool partitioned into per-device shards.

Methodology: device-level scaling is only visible when a device is a fixed
execution resource, so the workers pin XLA to the legacy single-threaded
CPU runtime (``--xla_cpu_use_thunk_runtime=false
--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1``) --
otherwise the 1-device baseline silently spreads over every core and the
comparison measures thread-pool contention, not sharding.  Device counts
are *interleaved* across rounds (1,2,4,1,2,4,...) and each config keeps its
best round, so slow-host noise hits every config equally.  The report also
records a **process-parallel calibration**: the combined throughput of two
*independent* single-device worker processes, i.e. the host's actual
parallel headroom -- on a 2-core container the in-process 4-device speedup
is bounded by (and should be read against) that ceiling, while CI's
4-vCPU leg and real multi-device hardware have room to show the full
fan-out.

The workload is a deep 256-wide LIF chain (the paper's 256-neuron cores
stacked five deep): wide enough per layer to be compute-bound, the regime
where device sharding pays.

Emits ``BENCH_shard.json`` at the repo root (full runs) or
``experiments/BENCH_shard_fast.json`` (``--fast`` smoke; what CI uploads)
and returns the harness's ``(name, us_per_call, derived)`` rows.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = _ROOT / "BENCH_shard.json"
FAST_OUT = _ROOT / "experiments" / "BENCH_shard_fast.json"

#: Per-device single-thread pinning (see module docstring).
SINGLE_THREAD_FLAGS = (
    "--xla_cpu_use_thunk_runtime=false "
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
)
DEVICE_COUNTS = (1, 2, 4)


# ---------------------------------------------------------------------------
# Worker: runs in a fresh process with the forced device count
# ---------------------------------------------------------------------------


def _worker(cfg: dict) -> None:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import backend as backend_lib
    from repro.core import shard as shard_lib
    from repro.core.network import NetworkConfig, init_float_params, quantize_params
    from repro.core.snn_layer import LayerConfig, NeuronModel
    from repro.serve.snn_engine import SNNRequest, SNNServeEngine

    n_dev = len(jax.devices())
    assert n_dev == cfg["devices"], (n_dev, cfg)
    fast = cfg["fast"]
    T = 8 if fast else 16
    B = 256 if fast else 1024  # eval batch (divisible by every device count)
    P = 8  # DSE population width
    dse_batch = 64 if fast else 128
    rounds, calls = (2, 1) if fast else (4, 2)

    def wide(n_out=256):
        return LayerConfig(n_in=256, n_out=n_out, neuron=NeuronModel.LIF, w_bits=6, u_bits=16)

    net = NetworkConfig(
        layers=(wide(), wide(), wide(), wide(), wide(10)),
        n_steps=T,
        name="shard-bench-256x4-10",
    )
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)
    mesh = shard_lib.make_mesh()  # all (forced) devices; 1 device -> serial path
    spikes = (jax.random.uniform(jax.random.PRNGKey(1), (T, B, 256)) < 0.15).astype(jnp.int32)

    def best_of(fn) -> float:
        """Best (min) seconds-per-call over interleave-friendly rounds."""
        fn()  # compile
        best = float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            for _ in range(calls):
                fn()
            best = min(best, (time.perf_counter() - t0) / calls)
        return best

    report: dict = {"devices": n_dev}

    if cfg["metric"] in ("all", "eval"):
        sec = best_of(
            lambda: shard_lib.run_int_sharded(
                net, qparams, spikes, mesh
            ).spike_counts.block_until_ready()
        )
        report["eval"] = {"seconds_per_pass": sec, "samples_per_sec": B / sec}

    if cfg["metric"] in ("all", "dse"):
        bits = (4, 5, 6, 8, 12, 16, 4, 8)
        cands = [net.replace_precisions(w_bits=b) for b in bits[:P]]
        qps = [quantize_params(c, params)[0] for c in cands]
        stacked, beta, alpha = backend_lib.stack_population(cands, qps)
        sp = spikes[:, :dse_batch]
        sec = best_of(
            lambda: shard_lib.run_int_population_sharded(
                net, stacked, beta, alpha, sp, mesh
            ).block_until_ready()
        )
        report["dse"] = {
            "seconds_per_sweep": sec,
            "population": P,
            "eval_batch": dse_batch,
            "candidates_per_sec": P / sec,
        }

    if cfg["metric"] in ("all", "serve"):
        n_req = 16 if fast else 64
        rng = np.random.default_rng(0)
        rasters = [(rng.random((T, 256)) < 0.15).astype(np.uint8) for _ in range(n_req)]

        def serve_pass():
            eng = SNNServeEngine(
                net, qparams, max_batch=8, data_parallel=n_dev, tick_stride=T
            )
            reqs = [SNNRequest(uid=i, raster=r) for i, r in enumerate(rasters)]
            for r in reqs:
                eng.submit(r)
            t0 = time.perf_counter()
            done = eng.drain()
            assert len(done) == n_req
            return time.perf_counter() - t0

        serve_pass()  # compile
        best = min(serve_pass() for _ in range(rounds))
        report["serve"] = {
            "seconds_per_pass": best,
            "requests": n_req,
            "samples_per_sec": n_req / best,
        }

    print("SHARD_WORKER_RESULT " + json.dumps(report))


def _spawn(devices: int, fast: bool, metric: str = "all") -> subprocess.Popen:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} {SINGLE_THREAD_FLAGS}"
    )
    env["JAX_PLATFORMS"] = "cpu"  # host-device scaling is a CPU measurement
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    cfg = json.dumps({"devices": devices, "fast": fast, "metric": metric})
    return subprocess.Popen(
        [sys.executable, "-m", "benchmarks.shard_bench", "--worker", cfg],
        cwd=_ROOT,
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )


def _collect(proc: subprocess.Popen) -> dict:
    out, err = proc.communicate()
    for line in out.splitlines():
        if line.startswith("SHARD_WORKER_RESULT "):
            return json.loads(line[len("SHARD_WORKER_RESULT "):])
    raise RuntimeError(f"shard worker failed:\n{err[-2000:]}")


def run(fast: bool = False, device_counts=DEVICE_COUNTS, rounds: int | None = None):
    rounds = (2 if fast else 3) if rounds is None else rounds
    best: dict[int, dict] = {n: {} for n in device_counts}
    # interleave device counts across rounds: host noise hits every config
    for _ in range(rounds):
        for n in device_counts:
            res = _collect(_spawn(n, fast))
            for metric in ("eval", "dse", "serve"):
                key = "candidates_per_sec" if metric == "dse" else "samples_per_sec"
                cur = best[n].get(metric)
                if cur is None or res[metric][key] > cur[key]:
                    best[n][metric] = res[metric]

    # calibration: two independent 1-device processes = the host's real
    # parallel headroom (ideal on unshared multi-core hardware: ~2.0)
    procs = [_spawn(1, fast, metric="eval") for _ in range(2)]
    combined = sum(_collect(p)["eval"]["samples_per_sec"] for p in procs)
    ceiling = combined / best[device_counts[0]]["eval"]["samples_per_sec"]

    base = best[device_counts[0]]
    top = best[device_counts[-1]]
    report = {
        "workload": "shard-bench-256x4-10",
        # in-process fan-out speedup relative to what the host can physically
        # deliver (1.0 = the sharded layer extracted every available core)
        "parallel_efficiency_vs_ceiling": (
            top["eval"]["samples_per_sec"] / base["eval"]["samples_per_sec"]
        ) / max(ceiling, 1e-9),
        "device_counts": list(device_counts),
        "xla_flags": SINGLE_THREAD_FLAGS,
        "host_cpu_count": os.cpu_count(),
        "process_parallel_ceiling_x2": ceiling,
        "by_devices": {str(n): best[n] for n in device_counts},
        "speedups_vs_1_device": {
            str(n): {
                "eval_samples_per_sec_x": best[n]["eval"]["samples_per_sec"]
                / base["eval"]["samples_per_sec"],
                "dse_candidates_per_sec_x": best[n]["dse"]["candidates_per_sec"]
                / base["dse"]["candidates_per_sec"],
                "serve_samples_per_sec_x": best[n]["serve"]["samples_per_sec"]
                / base["serve"]["samples_per_sec"],
            }
            for n in device_counts[1:]
        },
    }

    out = FAST_OUT if fast else OUT
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(report, indent=2))

    rows = []
    for n in device_counts:
        b = best[n]
        rows.append(
            (
                f"shard/eval-{n}dev",
                b["eval"]["seconds_per_pass"] * 1e6,
                f"samples_per_sec={b['eval']['samples_per_sec']:.1f}",
            )
        )
        rows.append(
            (
                f"shard/dse-{n}dev",
                b["dse"]["seconds_per_sweep"] * 1e6,
                f"cand_per_sec={b['dse']['candidates_per_sec']:.2f}",
            )
        )
        rows.append(
            (
                f"shard/serve-{n}dev",
                b["serve"]["seconds_per_pass"] * 1e6,
                f"samples_per_sec={b['serve']['samples_per_sec']:.1f}",
            )
        )
    for n, s in report["speedups_vs_1_device"].items():
        rows.append(
            (
                f"shard/speedup-{n}dev",
                0.0,
                f"eval={s['eval_samples_per_sec_x']:.2f}x;dse={s['dse_candidates_per_sec_x']:.2f}x"
                f";serve={s['serve_samples_per_sec_x']:.2f}x;ceiling_x2={ceiling:.2f}x",
            )
        )
    return rows


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        _worker(json.loads(sys.argv[2]))
    else:
        fast = "--fast" in sys.argv
        for name, us, derived in run(fast=fast):
            print(f"{name},{us:.1f},{derived}")
