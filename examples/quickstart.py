"""Quickstart: train a Flexi-NeurA SNN, quantize it, check bit-exact accuracy.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU: trains the paper's 256-128-10 LIF network on the
synthetic MNIST stand-in, quantizes weights to 6 bits, evaluates the
hardware-bit-exact simulator, and prints the hardware model's
resources/latency/power next to the paper's reported design point.
"""

import numpy as np

from repro.core import hw_model
from repro.core.network import NetworkConfig, quantize_params
from repro.core.snn_layer import LayerConfig
from repro.data.snn_datasets import mnist_like
from repro.snn.train import eval_int, train_snn


def main():
    ds = mnist_like(n=2048, T=25, seed=0)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, w_bits=6, u_bits=8, beta=0.95),
            LayerConfig(n_in=128, n_out=10, w_bits=6, u_bits=8, beta=0.95),
        ),
        n_steps=25,
        name="quickstart-mnist",
    )
    print(f"training {net.name} (LIF 256-128-10, 6-bit weights)...")
    res = train_snn(net, train, epochs=8, batch_size=128, lr=2e-3, log_every=2)

    qparams, scales = quantize_params(net, res.params)
    acc, stats = eval_int(net, qparams, test, return_stats=True)
    print(f"\nbit-exact quantized accuracy: {acc:.4f}  (paper on real MNIST: 0.9723)")

    r = hw_model.network_resources(net)
    lat = hw_model.latency_seconds(net, stats["input_events_per_step"], stats["layer_events_per_step"])
    events = float(np.sum(stats["input_events_per_step"]) + sum(np.sum(e) for e in stats["layer_events_per_step"]))
    e_img = hw_model.energy_per_image(net, lat, events)
    print(f"resources: {r.logic_cells:.0f} logic cells ({r.lut:.0f} LUT + {r.ff:.0f} FF), {r.bram} BRAM  (paper: 1623, 7)")
    print(f"latency:   {lat*1e3:.2f} ms/img @ 60 MHz                         (paper: 1.1 ms at T=100)")
    print(f"power:     {hw_model.power_watts(net, events/lat)*1e3:.0f} mW, energy {e_img*1e3:.3f} mJ/img  (paper: 111 mW, 0.12 mJ)")


if __name__ == "__main__":
    main()
