"""Quickstart: train a Flexi-NeurA SNN, quantize it, check bit-exact accuracy.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU: trains the paper's 256-128-10 LIF network on the
synthetic MNIST stand-in, quantizes weights to 6 bits, evaluates the
hardware-bit-exact simulator, and prints the hardware model's
resources/latency/power next to the paper's reported design point.
"""

from repro.core import hw_model
from repro.core.network import NetworkConfig, quantize_params
from repro.core.snn_layer import LayerConfig
from repro.data.snn_datasets import mnist_like
from repro.snn.train import eval_int, train_snn


def main():
    ds = mnist_like(n=2048, T=25, seed=0)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, w_bits=6, u_bits=8, beta=0.95),
            LayerConfig(n_in=128, n_out=10, w_bits=6, u_bits=8, beta=0.95),
        ),
        n_steps=25,
        name="quickstart-mnist",
    )
    print(f"training {net.name} (LIF 256-128-10, 6-bit weights)...")
    res = train_snn(net, train, epochs=8, batch_size=128, lr=2e-3, log_every=2)

    qparams, scales = quantize_params(net, res.params)
    # the event-driven backend exploits the trained network's sparsity;
    # bit-exact vs reference, so the accuracy is the same number
    acc, stats = eval_int(net, qparams, test, return_stats=True, backend="event")
    print(f"\nbit-exact quantized accuracy: {acc:.4f}  (paper on real MNIST: 0.9723)")

    r = hw_model.network_resources(net)
    traffic = hw_model.EventTraffic.from_stats(stats)
    dp = hw_model.design_point(net, traffic)
    print(f"resources: {r.logic_cells:.0f} logic cells ({r.lut:.0f} LUT + {r.ff:.0f} FF), {r.bram} BRAM  (paper: 1623, 7)")
    print(f"latency:   {dp.latency_s*1e3:.2f} ms/img @ 60 MHz at {dp.events_per_image:.0f} events/img  (paper: 1.1 ms at T=100)")
    print(f"power:     {dp.power_w*1e3:.0f} mW, energy {dp.energy_per_image_j*1e3:.3f} mJ/img  (paper: 111 mW, 0.12 mJ)")


if __name__ == "__main__":
    main()
