"""Continuous-batching SNN serving over the backend registry.

    PYTHONPATH=src python examples/serve_snn.py

Serves a mixed stream of quantized-SNN inference requests -- dense
mnist-like digits, a couple of very sparse event streams, and one short
window -- through ``SNNServeEngine`` with the event backend's density-based
admission policy, then re-runs every request serially through ``run_int``
and checks the served outputs are bit-identical.  Prints per-request
predictions, wall-clock latency, the route each request took, and the
modeled hardware operating point (latency / energy) at each request's own
measured event traffic.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.network import NetworkConfig, init_float_params, quantize_params, run_int
from repro.core.snn_layer import LayerConfig, NeuronModel
from repro.data.snn_datasets import mnist_like
from repro.serve.snn_engine import SNNRequest, SNNServeEngine


def main():
    T = 20
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
            LayerConfig(n_in=128, n_out=10, neuron=NeuronModel.LIF, w_bits=6, u_bits=16),
        ),
        n_steps=T,
        name="serve-demo-256-128-10",
    )
    params = init_float_params(jax.random.PRNGKey(0), net)
    qparams, _ = quantize_params(net, params)

    # a mixed request stream: dense digits, sparse event streams, a short window
    ds = mnist_like(n=8, T=T, seed=3)
    rng = np.random.default_rng(0)
    rasters = [ds.spikes[i] for i in range(8)]
    rasters += [(rng.random((T, 256)) < 0.02).astype(np.uint8) for _ in range(2)]
    rasters.append(ds.spikes[0][: T // 2])  # short request: frees its lane early

    engine = SNNServeEngine(net, qparams, max_batch=4, backend="event")
    # precompile both routes so the printed latencies are service, not jit
    engine.warmup()
    requests = [SNNRequest(uid=i, raster=r) for i, r in enumerate(rasters)]
    done = engine.run(requests)

    print(f"served {len(done)} requests on {net.name} "
          f"(max_batch=4, backend=event, ticks={engine.n_ticks})")
    for r in sorted(done, key=lambda r: r.uid):
        dp = r.design
        print(
            f"  req{r.uid:>2}: T={r.n_steps:>2} density={r.density:5.1%} "
            f"route={r.route:<11} pred={r.prediction} "
            f"latency={r.latency_s * 1e3:6.2f} ms | modeled HW: "
            f"{dp.latency_s * 1e3:5.2f} ms / {dp.energy_per_image_j * 1e3:.3f} mJ"
        )

    # the service is an execution strategy, not a numerics change: every
    # request's outputs must match a serial batch-1 run_int bit-for-bit
    mismatches = 0
    for r in done:
        ref = run_int(net, qparams, jnp.asarray(r.raster[:, None, :], jnp.int32))
        mismatches += int(
            not np.array_equal(r.spike_counts, np.asarray(ref.spike_counts)[0])
        )
    print(f"\nbit-exact vs serial run_int: {len(done) - mismatches}/{len(done)} requests")
    assert mismatches == 0


if __name__ == "__main__":
    main()
