"""End-to-end LM training driver: a ~100M-parameter stablelm-family model
trained for a few hundred steps with the full production loop -- sharded
steps, async checkpointing, automatic resume, straggler telemetry, and an
injected node failure it recovers from.

    PYTHONPATH=src python examples/lm_train_100m.py [--steps 300]

Runs on CPU in ~10-20 minutes at the default 300 steps (use --steps 120 for
a quicker pass).  The same TrainLoop drives the full-size configs on the
production mesh (launch/train.py).
"""

import argparse
import dataclasses
import json

import jax

from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_arch
from repro.train.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--run-dir", default="runs/lm100m")
    args = ap.parse_args()

    arch = get_arch("stablelm-1.6b")
    # ~100M config of the same family: 12 x 512 with the arch's MHA/rope_frac
    cfg = dataclasses.replace(
        arch.reduced_config,
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
        d_ff=1408, vocab=8192,
    )
    arch = dataclasses.replace(arch, reduced_config=cfg)
    n_params = sum(x.size for x in jax.tree.leaves(arch.init_params(jax.random.PRNGKey(0), cfg)))
    print(f"model: stablelm-family {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} vocab={cfg.vocab})")

    loop = TrainLoop(
        arch_name="stablelm-1.6b",
        seq_len=256,
        global_batch=8,
        mesh=make_host_mesh(),
        run_dir=args.run_dir,
        ckpt_every=50,
        log_every=10,
        fail_at_step=args.steps // 2,  # prove the restart path mid-run
    )
    loop.arch = arch
    loop.cfg = cfg
    out = loop.run(total_steps=args.steps)
    print(json.dumps(out, indent=2))
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} over {out['final_step']} steps "
          f"with {out['failures']} recovered failure(s)")


if __name__ == "__main__":
    main()
