"""Batched serving with Flex-plorer-chosen weight precision.

    PYTHONPATH=src python examples/serve_quantized.py

Serves a reduced gemma2-family model with continuous batching, twice: at
full precision and with the paper's technique applied (int8 attention +
int4 MLP weights via the quant_matmul path).  Prints the outputs side by
side and the modeled decode-step memory traffic for the full-size config --
the number the decode_32k roofline cells are bound by.
"""

import time

import jax
import numpy as np

from repro.core.precision import PrecisionPolicy
from repro.distributed.structural import structural_bytes
from repro.models.registry import SHAPES, get_arch
from repro.serve.engine import Request, ServeEngine


def main():
    arch = get_arch("gemma2-27b")
    params = arch.init_params(jax.random.PRNGKey(0), arch.reduced_config)
    prompts = [np.asarray([11, 42, 7]), np.asarray([99, 3]), np.asarray([5, 5, 5, 5])]

    results = {}
    for label, policy in [
        ("bf16", None),
        ("int8-attn/int4-mlp", PrecisionPolicy(rules=(
            (r"(wq|wk|wv|wo)$", 8), (r"(w_gate|w_up|w_down)$", 4),
        ))),
    ]:
        eng = ServeEngine(arch, params, max_batch=2, max_len=64, quant=policy)
        t0 = time.time()
        done = eng.run([Request(uid=i, prompt=p, max_new_tokens=8) for i, p in enumerate(prompts)])
        results[label] = {r.uid: r.generated for r in done}
        print(f"[{label:>18}] served {len(done)} requests in {time.time()-t0:.1f}s")
        for uid in sorted(results[label]):
            print(f"    req{uid}: {results[label][uid]}")

    agree = sum(
        results["bf16"][u] == results["int8-attn/int4-mlp"][u] for u in results["bf16"]
    )
    print(
        f"\ngreedy outputs identical under int8/int4: {agree}/{len(prompts)} "
        "(random-init weights give near-uniform logits, so argmax is "
        "quantization-sensitive here; trained-weight fidelity is what "
        "benchmarks/lm_dse.py scores, and the int8-KV path is "
        "greedy-preserving in tests/test_precision_paths.py)"
    )

    shape = SHAPES["decode_32k"]
    base = structural_bytes(arch, shape)["total"]
    q8 = structural_bytes(arch, shape, quant_bits=8)["total"]
    q4 = structural_bytes(arch, shape, quant_bits=4)["total"]
    print(
        f"\nfull-size gemma2-27b decode_32k memory traffic per device per step:\n"
        f"  bf16/f32 weights: {base/1e9:.2f} GB  -> {base/819e9*1e6:.0f} us/step at HBM roofline\n"
        f"  int8 weights:     {q8/1e9:.2f} GB  -> {q8/819e9*1e6:.0f} us/step\n"
        f"  int4 weights:     {q4/1e9:.2f} GB  -> {q4/819e9*1e6:.0f} us/step"
    )


if __name__ == "__main__":
    main()
