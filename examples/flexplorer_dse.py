"""Flex-plorer end-to-end: train -> anneal -> QAT-refine -> deployment package.

    PYTHONPATH=src python examples/flexplorer_dse.py

The paper's full flow (Fig. 10) plus this repo's train-in-the-loop second
phase: the Learning stage trains an ATA-F LIF network on the DVS stand-in;
the Explorer anneals (ff bits, rec bits, leak precision) against the
weighted LUT/FF/BRAM + bit-exact-accuracy cost; ``refine_top_k`` then
QAT-fine-tunes the two best finalists at their own precisions (epoch 0 is
post-training quantization, so refinement never loses accuracy on the
scoring set); the "RTL Configurator" stage emits the deployment package our
framework's runtime consumes: chosen design-time parameters + quantized
weight tables + encoded dataset sample, under ``runs/flexplorer_pkg/`` --
from the best *refined* candidate when one dominates the annealer's pick.
"""

import json
import pathlib

import numpy as np

from repro.core import hw_model
from repro.core.flexplorer import annealer as annealer_lib
from repro.core.flexplorer import cost as cost_lib
from repro.core.flexplorer.explorer import RefineSpec, SearchSpec, SNNSearchSpace, explore_snn
from repro.core.network import NetworkConfig
from repro.core.snn_layer import LayerConfig, NeuronModel, Topology
from repro.data.snn_datasets import dvs_like
from repro.snn.train import train_snn


def _net_resources(net):
    res = hw_model.network_resources(net)
    return {
        "lut": float(res.lut),
        "ff": float(res.ff),
        "bram": float(res.bram),
        "logic_cells": float(res.logic_cells),
    }


def main():
    ds = dvs_like(n=1408, T=20, seed=2)
    train, test = ds.split()
    net = NetworkConfig(
        layers=(
            LayerConfig(n_in=256, n_out=128, neuron=NeuronModel.LIF, topology=Topology.ATA_F, u_bits=16),
            LayerConfig(n_in=128, n_out=11, neuron=NeuronModel.LIF, topology=Topology.FF, u_bits=16),
        ),
        n_steps=20,
        name="dvs-ataf",
    )
    print("Learning stage: training ATA-F LIF on DVS stand-in...")
    res = train_snn(net, train, epochs=6, batch_size=128, lr=2e-3, log_every=2)

    print("Explorer stage: simulated annealing over (ff, rec, leak) precision...")
    result = explore_snn(
        net,
        res.params,
        test,
        search=SearchSpec(
            space=SNNSearchSpace(ff_bits=(3, 4, 6, 8), rec_bits=(3, 4, 6, 8), leak_bits=(3, 8)),
            weights=cost_lib.CostWeights(c_hw=0.5, c_acc=0.5, c_lut=0.33, c_ff=0.33, c_bram=0.34),
            config=annealer_lib.AnnealConfig(t_start=1.0, t_min=0.05, alpha=0.6, eval_divisor=3, seed=0),
        ),
        refine=RefineSpec(top_k=2, train_ds=train, epochs=3, lr=1.5e-3),
    )
    report = result.report()
    print("chosen configuration:", json.dumps(report["chosen"], indent=2, default=float))
    print("explored (PTQ) Pareto front:", json.dumps(result.explored_front(), default=float))
    print("refined Pareto front:      ", json.dumps(result.refined_front(), default=float))
    for r in result.refined:
        print(f"  refined {r.breakdown}: {r.base_accuracy:.4f} -> {r.accuracy:.4f}")

    # deploy the best refined candidate when one beats the annealer's pick
    # at no higher total cost; the PTQ incumbent otherwise
    best_refined = min(result.refined, key=lambda r: r.total_cost, default=None)
    if best_refined is not None and best_refined.total_cost <= result.anneal.best_cost:
        deploy_net, deploy_qparams = best_refined.net, best_refined.qparams
        print(f"deploying refined candidate {best_refined.breakdown}")
    else:
        deploy_net, deploy_qparams = result.best_net, result.best_qparams
        print("deploying the unrefined annealer incumbent")

    out = pathlib.Path("runs/flexplorer_pkg")
    out.mkdir(parents=True, exist_ok=True)
    # deployment package: design-time params, quantized weights, encoded data
    (out / "design.json").write_text(json.dumps({
        "layers": [
            {"n_in": lc.n_in, "n_out": lc.n_out, "neuron": lc.neuron.value,
             "topology": lc.topology.value, "w_bits": lc.w_bits,
             "w_rec_bits": lc.w_rec_bits, "leak_bits": lc.leak_bits,
             "decay_register": lc.beta_code().decay_rate_register}
            for lc in deploy_net.layers
        ],
        # resources of the *deployed* net (refined candidates can differ
        # from the annealer incumbent the report above describes)
        "resources": _net_resources(deploy_net),
    }, indent=2))
    np.savez(out / "weights_q.npz", **{
        f"layer{i}_wff": np.asarray(q.w_ff) for i, q in enumerate(deploy_qparams)
    })
    np.save(out / "encoded_sample.npy", test.spikes[:16])
    print(f"deployment package written to {out}/ (design.json, weights_q.npz, encoded_sample.npy)")


if __name__ == "__main__":
    main()
